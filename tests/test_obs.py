"""Telemetry subsystem: metrics registry + exporters, JSONL events, span
tracing, solver convergence callbacks (the paper's monotone-descent
guarantee as a monitored invariant), and the BENCH_*.json snapshot
schema."""
import importlib.util
import json
import os
import sys
import urllib.request

import numpy as np
import pytest

from repro.core import cox, solvers
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.obs import TelemetryCallback, events, metrics, trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_bench_run():
    spec = importlib.util.spec_from_file_location(
        "bench_run_for_tests", os.path.join(ROOT, "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("bench_run_for_tests", mod)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture
def sinks_off():
    """Guarantee both global sinks are off for the test, restore after."""
    events.configure(None)
    trace.configure(None)
    yield
    events.configure(None)
    trace.configure(None)


# ---------------------------------------------------------------------------
# Metrics: counters / gauges / histograms, snapshot, Prometheus text
# ---------------------------------------------------------------------------

def test_counter_labels_and_total():
    reg = metrics.Registry()
    c = reg.counter("reqs_total", "requests", ("kind",))
    c.inc(kind="a")
    c.inc(2, kind="b")
    assert c.value(kind="a") == 1.0
    assert c.value(kind="b") == 2.0
    assert c.total() == 3.0
    with pytest.raises(ValueError):
        c.inc(-1, kind="a")
    with pytest.raises(ValueError):
        c.inc(kind="a", extra="nope")


def test_gauge_up_down():
    g = metrics.Registry().gauge("depth")
    g.set(5)
    g.inc()
    g.dec(2)
    assert g.value() == 4.0


def test_histogram_bucketing_and_inf_bucket():
    reg = metrics.Registry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    s = h._series()[()]
    assert s["counts"] == [1, 2, 1, 1]          # last bin is +Inf
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(56.05)


def test_registry_get_or_create_and_type_conflict():
    reg = metrics.Registry()
    assert reg.counter("c") is reg.counter("c")
    with pytest.raises(ValueError):
        reg.gauge("c")
    with pytest.raises(ValueError):
        reg.counter("c", label_names=("x",))


def test_prometheus_text_format():
    reg = metrics.Registry()
    reg.counter("served_total", "served", ("kind",)).inc(3, kind="risk")
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE served_total counter" in text
    assert 'served_total{kind="risk"} 3' in text
    # cumulative le-buckets + the implicit +Inf
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text


def test_snapshot_satisfies_bench_schema():
    run = _load_bench_run()
    reg = metrics.Registry()
    reg.counter("a_total", "", ("k",)).inc(k="x")
    reg.gauge("g").set(2)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    snap = reg.snapshot()
    assert run.validate_metrics_snapshot(snap) == []
    json.dumps(snap)                            # JSON-able end to end


def test_snapshot_schema_rejects_malformed():
    run = _load_bench_run()
    assert run.validate_metrics_snapshot([]) != []
    assert run.validate_metrics_snapshot({}) != []
    bad = {"counters": {"c": {"": "NaN-string"}}, "gauges": {},
           "histograms": {"h": {"buckets": [1.0],
                                "series": {"": {"counts": [1],  # wrong len
                                                "sum": 0.0, "count": 1}}}}}
    errs = run.validate_metrics_snapshot(bad)
    assert any("counters/c" in e for e in errs)
    assert any("histograms/h" in e for e in errs)


def test_serve_metrics_http_endpoint():
    reg = metrics.Registry()
    reg.counter("hits_total").inc(7)
    server = metrics.serve_metrics(port=0, registry=reg)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert "hits_total 7" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=5)
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# Events + spans
# ---------------------------------------------------------------------------

def test_event_sink_roundtrip(tmp_path, sinks_off):
    path = str(tmp_path / "events.jsonl")
    events.configure(path)
    try:
        events.emit("unit.test", a=1, arr=np.float32(2.5))
        assert events.enabled()
    finally:
        events.configure(None)
    recs = events.read_jsonl(path)
    assert len(recs) == 1
    assert recs[0]["kind"] == "unit.test"
    assert recs[0]["a"] == 1
    assert recs[0]["arr"] == 2.5               # numpy coerced, not crashed
    assert "ts" in recs[0]


def test_span_noop_when_disabled(sinks_off):
    assert not trace.enabled()
    sp = trace.span("x", attr=1)
    assert sp is trace.span("y")                # shared no-op singleton
    with sp as s:
        s.set(more=2)


def test_span_nesting_and_trace_ids(tmp_path, sinks_off):
    path = str(tmp_path / "trace.jsonl")
    trace.configure(path)
    try:
        with trace.span("root", tag="r") as root:
            with trace.span("child"):
                with trace.span("grandchild"):
                    pass
            trace.emit_span("retro", 0.25, rid=7)
        with trace.span("root2"):
            pass
    finally:
        trace.configure(None)
    spans = {r["name"]: r for r in events.read_jsonl(path)}
    assert len(spans) == 5
    tid = spans["root"]["trace_id"]
    for name in ("child", "grandchild", "retro"):
        assert spans[name]["trace_id"] == tid
    assert spans["child"]["parent_id"] == spans["root"]["span_id"]
    assert spans["grandchild"]["parent_id"] == spans["child"]["span_id"]
    assert spans["retro"]["parent_id"] == spans["root"]["span_id"]
    assert spans["retro"]["dur_s"] == 0.25
    assert spans["root"]["attrs"] == {"tag": "r"}
    assert spans["root2"]["trace_id"] != tid    # fresh root, fresh trace
    assert all(s["dur_s"] >= 0 for s in spans.values())
    assert root.trace_id == tid


def test_latency_breakdown_table_renders(tmp_path, sinks_off):
    from repro.analysis.report import latency_breakdown_table
    path = str(tmp_path / "trace.jsonl")
    trace.configure(path)
    try:
        with trace.span("service.step"):
            with trace.span("service.dispatch"):
                pass
            with trace.span("service.dispatch"):
                pass
    finally:
        trace.configure(None)
    table = latency_breakdown_table(path)
    lines = table.splitlines()
    assert lines[0].startswith("| stage ")
    assert any(ln.startswith("| service.step | 1 ") for ln in lines)
    assert any(ln.startswith("| service.dispatch | 2 ") for ln in lines)
    # empty file degrades to a hint row, not a crash
    empty = str(tmp_path / "empty.jsonl")
    open(empty, "w").close()
    assert "no spans" in latency_breakdown_table(empty)


# ---------------------------------------------------------------------------
# Solver convergence telemetry
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_problem():
    x, t, delta, _ = make_correlated_survival(
        SyntheticSpec(n=200, p=15, k=3, rho=0.3, seed=4))
    return cox.prepare(x, t, delta)


def test_fit_cd_telemetry_matches_objective_and_no_violations(
        small_problem, sinks_off):
    import jax
    reg = metrics.Registry()
    tel = TelemetryCallback("cd_quad", registry=reg)
    res = solvers.fit_cd(small_problem, lam2=0.1, n_iters=20, telemetry=tel)
    res.beta.block_until_ready()
    jax.effects_barrier()
    assert tel.iterations == 20
    assert tel.violations == 0
    # recorded objectives are the solver's own per-iteration objectives
    np.testing.assert_allclose(tel.objectives,
                               np.asarray(res.objective), rtol=1e-5)
    assert np.all(np.diff(tel.objectives) <= tel.tol)
    assert reg.counter("solver_iterations_total",
                       label_names=("solver",)).value(solver="cd_quad") == 20


def test_fit_cd_tol_telemetry_counts_iterations(small_problem, sinks_off):
    import jax
    tel = TelemetryCallback("cd_tol", registry=metrics.Registry())
    solvers.fit_cd_tol(small_problem, 0.0, 0.1, max_iters=30,
                       telemetry=tel).beta.block_until_ready()
    jax.effects_barrier()
    assert 1 <= tel.iterations <= 30
    assert tel.violations == 0
    rec = tel.records[0]
    assert {"iter", "objective", "grad_norm", "step_norm",
            "active_set"} <= set(rec)


def test_broken_step_increments_violation_counter(sinks_off):
    tel = TelemetryCallback("broken", tol=1e-6,
                            registry=metrics.Registry())
    # a deliberately non-monotone objective sequence: 5 -> 4 -> 4.5 -> 3
    for it, obj in enumerate((5.0, 4.0, 4.5, 3.0)):
        tel._cb(it, obj, 0.0, 0.0, 0)
    assert tel.violations == 1
    assert tel.iterations == 4


def test_violation_check_is_arrival_order_independent(sinks_off):
    tel = TelemetryCallback("ooo", registry=metrics.Registry())
    # same broken sequence, callbacks landing out of order (unordered
    # jax.debug.callback semantics): each adjacent pair still checked once
    seq = {0: 5.0, 1: 4.0, 2: 4.5, 3: 3.0}
    for it in (2, 0, 3, 1):
        tel._cb(it, seq[it], 0.0, 0.0, 0)
    assert tel.violations == 1


def test_newton_without_line_search_is_caught(sinks_off):
    """The broken solver the paper critiques (Fig. 1a: raw Newton
    overshoots from beta=0 on rare heavy-tailed features) is exactly what
    the violation counter must flag — same data as
    test_solvers.test_exact_newton_blows_up_without_line_search."""
    import jax
    rng = np.random.default_rng(1)
    n, p = 120, 4
    x = ((rng.uniform(size=(n, p)) < 0.04)
         * rng.lognormal(1.5, 1.0, size=(n, p))).astype(np.float64)
    risk = np.clip(x @ np.array([3.0, -3.0, 2.0, -2.0]), -30, 30)
    t = (-np.log(rng.uniform(1e-12, 1, n)) / np.exp(risk)) ** 0.3
    delta = (rng.uniform(size=n) < 0.8).astype(np.float64)
    data = cox.prepare(x, t, delta)
    tel = TelemetryCallback("newton_raw", registry=metrics.Registry())
    solvers.fit_newton(data, lam2=0.0, n_iters=12, line_search=False,
                       telemetry=tel).beta.block_until_ready()
    jax.effects_barrier()
    assert tel.violations >= 1


def test_telemetry_none_is_free(small_problem):
    # telemetry=None must stage no callback: same jit cache entry count
    # behaviour as the pre-telemetry solver, and no iterations recorded
    res = solvers.fit_cd(small_problem, lam2=0.1, n_iters=5, telemetry=None)
    assert np.isfinite(float(res.objective[-1]))


def test_solver_events_emitted(tmp_path, small_problem, sinks_off):
    import jax
    path = str(tmp_path / "solver_events.jsonl")
    events.configure(path)
    try:
        tel = TelemetryCallback("evt", registry=metrics.Registry())
        solvers.fit_cd(small_problem, lam2=0.1, n_iters=5,
                       telemetry=tel).beta.block_until_ready()
        jax.effects_barrier()
    finally:
        events.configure(None)
    iters = [r for r in events.read_jsonl(path)
             if r["kind"] == "solver.iter"]
    assert len(iters) == 5
    assert all(r["solver"] == "evt" for r in iters)


# ---------------------------------------------------------------------------
# Bench embedding: the instrumented smoke-fit record
# ---------------------------------------------------------------------------

def test_telemetry_record_validates_and_counts_zero_violations(sinks_off):
    run = _load_bench_run()
    rec = run._telemetry_record("cpu", tuned={}, git_rev="test",
                                n_iters=10)
    assert run.validate_records([rec]) == []
    assert run.validate_metrics_snapshot(rec["metrics"]) == []
    assert rec["value"] == 0.0
    assert run._solver_violations(rec["metrics"]) == 0.0
    cs = rec["metrics"]["counters"]
    assert "solver_iterations_total" in cs
