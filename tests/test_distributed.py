"""Distributed CPH (shard_map) correctness on 8 host devices.

Runs in a subprocess so the main pytest process keeps 1 device (the
harness contract: only the dry-run and explicit distributed tests may
fork the device count)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import cox, distributed, solvers
from repro.launch.mesh import _make_mesh, shard_map_compat
from repro.train.compression import compressed_psum

mesh = _make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
# odd n (not divisible by the 4-way data axis): exercises the padded-tail
# remainder-shard path in every entry point below
n, p = 509, 32
x = rng.standard_normal((n, p)).astype(np.float32)
t = rng.uniform(1.0, 2.0, size=n).astype(np.float32)  # continuous: no ties
delta = (rng.uniform(size=n) < 0.7).astype(np.float32)
data = cox.prepare(x, t, delta)
beta = rng.standard_normal(p).astype(np.float32) * 0.3
eta = np.asarray(data.x @ beta)

# --- sharded suffix sum (1d + 2d), remainder tail
v = jnp.asarray(rng.standard_normal(n), jnp.float32)
out = distributed.shard_revcumsum(v, mesh)
np.testing.assert_allclose(np.asarray(out),
                           np.asarray(jax.lax.cumsum(v, reverse=True)),
                           rtol=2e-5, atol=2e-5)
v2 = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
out2 = distributed.shard_revcumsum_2d(v2, mesh)
np.testing.assert_allclose(np.asarray(out2),
                           np.asarray(jax.lax.cumsum(v2, axis=0,
                                                     reverse=True)),
                           rtol=2e-5, atol=2e-5)
print("revcumsum ok")

# --- sharded risk stats match the replicated reference
w_sh, s0_sh, a_sh = distributed.sharded_risk_stats(data, jnp.asarray(eta),
                                                   mesh)
w_r, s0_r, a_r, _ = cox.risk_stats(data, jnp.asarray(eta))
np.testing.assert_allclose(np.asarray(s0_sh), np.asarray(s0_r),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(a_sh), np.asarray(a_r),
                           rtol=2e-4, atol=2e-4)
print("risk stats ok")

# --- sharded all-coordinate derivatives
g_sh, h_sh = distributed.sharded_grad_hess_all(data, jnp.asarray(eta), mesh)
g_ref, h_ref = cox.grad_hess_all(data, jnp.asarray(eta))
np.testing.assert_allclose(np.asarray(g_sh), np.asarray(g_ref),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(h_sh), np.asarray(h_ref),
                           rtol=2e-4, atol=2e-4)
print("grad_hess ok")

# --- sharded CD reaches the same objective as replicated CD
l2c, _ = cox.lipschitz_constants(data)
beta_sh, eta_out = distributed.fit_cd_sharded(
    data, jnp.asarray(l2c), mesh, lam2=0.5, n_sweeps=12)
res = solvers.fit_cd(data, lam2=0.5, n_iters=12)
f_sh = float(cox.loss_from_eta(data, jnp.asarray(eta_out))
             + 0.5 * jnp.sum(beta_sh * beta_sh))
f_ref = float(res.objective[-1])
assert abs(f_sh - f_ref) < 1e-2 * max(1.0, abs(f_ref)), (f_sh, f_ref)
print("cd ok", f_sh, f_ref)

# --- compressed psum ~= psum
y = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
exact = shard_map_compat(lambda a: jax.lax.psum(a, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P("data"))(y)
approx = shard_map_compat(lambda a: compressed_psum(a, "data"), mesh=mesh,
                          in_specs=P("data"), out_specs=P("data"))(y)
rel = float(jnp.sqrt(jnp.mean((approx - exact) ** 2))
            / jnp.sqrt(jnp.mean(exact ** 2)))
assert rel < 0.02, rel  # int8 wire format: ~1% normalized RMSE
print("compressed psum ok", rel)
print("ALL_OK")
"""


def test_distributed_cph_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ALL_OK" in out.stdout, out.stdout + "\n---\n" + out.stderr
