"""Deep-survival pipeline: train -> sparse refit -> artifact -> serving.

Tiny shapes throughout (the pipeline's full-size path is exercised by
examples/train_survival_lm.py and benchmarks/bench_deep.py); what's
locked here is the *contract*: losses finite and decreasing, the refit
head is genuinely k-sparse, the exported artifact round-trips through
disk + ModelRegistry and serves through RiskService with scores that
match the sparse head bit-for-bit.
"""
import numpy as np
import pytest

from repro.serving import ModelRegistry, RiskService, SurvivalModel
from repro.survival import deep


@pytest.fixture(scope="module")
def result():
    return deep.run(steps=16, batch=16, seq=20, k=4, refit_batches=2,
                    log_every=0, warmup_steps=4)


def test_training_losses_finite_and_improving(result):
    assert len(result.losses) == 16
    assert np.isfinite(result.losses).all()
    assert np.mean(result.losses[-4:]) < np.mean(result.losses[:4]) + 0.05


def test_sparse_head_is_k_sparse(result):
    assert result.nnz <= 4
    assert result.beta.shape == (result.cfg.d_model,)
    assert len(result.beam.supports[-1]) == result.nnz


def test_cindexes_beat_random(result):
    assert result.cindex_deep > 0.5
    assert result.cindex_sparse > 0.5


def test_artifact_shape_and_sparsity(result):
    art = result.artifact
    assert art.p == result.cfg.d_model
    assert art.is_sparse and art.k == result.nnz
    assert art.base_cumhaz.shape == (1, art.n_grid)
    # cumulative hazard is nonnegative and monotone on the grid
    assert (art.base_cumhaz >= 0).all()
    assert (np.diff(art.base_cumhaz, axis=1) >= -1e-6).all()


def test_artifact_roundtrip_and_serving(result, tmp_path):
    path = str(tmp_path / "deep_artifact")
    result.artifact.save(path)
    loaded = SurvivalModel.load(path)
    np.testing.assert_array_equal(loaded.beta, result.artifact.beta)

    svc = RiskService(None, max_batch=8)
    reg = ModelRegistry(svc, prewarm_batches=(1, 8))
    reg.rollout("deep_v1", path)
    svc.start()
    try:
        rids = [svc.submit(f) for f in result.features[:8]]
        served = np.array([svc.wait(r).risk for r in rids])
    finally:
        svc.stop()
    expect = np.exp(np.clip(result.features[:8] @ result.beta, -30., 30.))
    np.testing.assert_allclose(served, expect, rtol=1e-4)
    assert reg.get("deep_v1").state == "live"


def test_featurizer_matches_collected_features(result):
    from repro.data.pipeline import SurvivalTextStream
    from repro.models import build_model
    model = build_model(result.cfg)
    featurize = deep.make_featurizer(model)
    stream = SurvivalTextStream(result.cfg.vocab_size, 20, 16, seed=0)
    b = stream.batch_for_step(16)           # first held-out batch
    risk, feats = featurize(result.state.params, b)
    np.testing.assert_allclose(np.asarray(feats),
                               result.features[:16], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(risk),
                               result.risks_deep[:16], rtol=1e-5)


def test_config_override_and_full_path():
    dcfg = deep.DeepSurvivalConfig(full=True)
    cfg = deep.model_config(dcfg)
    assert cfg.n_layers == 12 and cfg.vocab_size == 2048
    reduced = deep.model_config(deep.DeepSurvivalConfig())
    assert reduced.d_model == 128 and reduced.vocab_size == 512
