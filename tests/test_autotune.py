"""Autotuner: shape-bucket edges, cache round-trip (no re-timing), default
fallback, ops dispatch through a tuned cache, and parity of every candidate
block config against the jnp references — including ragged/padded shapes."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref


# -- shape buckets ----------------------------------------------------------

def test_bucket_edges():
    assert autotune.bucket(1) == 1
    assert autotune.bucket(2) == 2
    assert autotune.bucket(3) == 4
    assert autotune.bucket(512) == 512
    assert autotune.bucket(513) == 1024
    assert autotune.bucket(0) == 1   # degenerate guard


def test_bucket_key_shape_and_backend():
    k = autotune.bucket_key("revcumsum", {"n": 1000, "m": 3}, backend="cpu")
    assert k == "cpu/revcumsum/n=1024,m=4"
    # every n in (512, 1024] lands in the same bucket
    assert autotune.bucket_key("revcumsum", {"n": 600, "m": 4},
                               backend="cpu") == k
    assert autotune.bucket_key("revcumsum", {"n": 1000, "m": 3},
                               backend="tpu") != k


def test_candidates_pruned_to_bucket_but_default_kept():
    default = autotune.DEFAULT_CONFIGS["survival_curves"]
    cands = autotune.candidates_for("survival_curves", {"b": 32, "g": 32})
    assert default in cands
    floor_b = min(c["block_b"]
                  for c in autotune.CANDIDATES["survival_curves"])
    for cfg in cands:
        if cfg != default:
            assert cfg["block_b"] <= max(32, floor_b)


# -- cache round-trip -------------------------------------------------------

def test_cache_roundtrip_no_retiming(tmp_path, monkeypatch):
    path = str(tmp_path / "tuned.json")
    shape = {"n": 96, "m": 4}
    cfg = autotune.autotune("revcumsum", shape, cache_file=path, reps=1)
    assert set(cfg) == {"block_n"}
    with open(path) as f:
        data = json.load(f)
    assert len(data["entries"]) == 1
    (entry,) = data["entries"].values()
    assert entry["config"] == cfg
    assert entry["default_config"] == autotune.DEFAULT_CONFIGS["revcumsum"]
    assert entry["us"] <= entry["default_us"] + 1e-9

    def boom(*a, **k):
        raise AssertionError("cached bucket was re-timed")

    monkeypatch.setattr(autotune, "_time_call", boom)
    # same bucket (n=70 -> 128, m=3 -> 4 just like n=96, m=4): cache hit
    assert autotune.autotune("revcumsum", {"n": 70, "m": 3},
                             cache_file=path) == cfg
    # a fresh process state reloads the same winners from disk
    autotune._LOADED.clear()
    assert autotune.autotune("revcumsum", shape, cache_file=path) == cfg


def test_lookup_falls_back_to_default(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "missing.json"))
    autotune._LOADED.clear()
    for kernel, default in autotune.DEFAULT_CONFIGS.items():
        shape = {a: 64 for a in autotune.SHAPE_AXES[kernel]}
        assert autotune.lookup(kernel, **shape) == default


def test_lookup_returns_tuned_winner(tmp_path, monkeypatch):
    path = str(tmp_path / "tuned.json")
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    key = autotune.bucket_key("revcumsum", {"n": 100, "m": 2})
    autotune.save_cache({key: {"config": {"block_n": 64}}}, path)
    assert autotune.lookup("revcumsum", n=100, m=2) == {"block_n": 64}
    # a different bucket still falls back to the default
    assert autotune.lookup("revcumsum", n=100_000, m=2) == \
        autotune.DEFAULT_CONFIGS["revcumsum"]


def test_ops_dispatch_consults_tuned_cache(tmp_path, monkeypatch):
    """ops.revcumsum with a tuned block produces reference results."""
    path = str(tmp_path / "tuned.json")
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    n = 200
    key = autotune.bucket_key("revcumsum", {"n": n, "m": 1})
    autotune.save_cache({key: {"config": {"block_n": 64}}}, path)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.revcumsum(x)),
                               np.asarray(ref.revcumsum_ref(x)),
                               rtol=1e-4, atol=1e-4)


def test_autotune_registers_into_roofline(tmp_path):
    from repro.analysis import roofline
    path = str(tmp_path / "tuned.json")
    shape = {"b": 16, "g": 16}
    autotune.autotune("survival_curves", shape, cache_file=path, reps=1)
    key = autotune.bucket_key("survival_curves", shape)
    assert key in roofline.TUNED_KERNELS
    assert "default_us" in roofline.TUNED_KERNELS[key]


# -- parity of every candidate config against the jnp references ------------

RAGGED_SHAPES = {
    "revcumsum": {"n": 333, "m": 5},
    "cox_coord": {"n": 517},
    "cox_batch": {"n": 261, "p": 19},
    "lipschitz": {"n": 300, "m": 7},
    "survival_curves": {"b": 77, "g": 33},
    "survival_curves_strat": {"b": 77, "g": 33},
}


def _reference(kernel, inputs):
    if kernel == "revcumsum":
        return ref.revcumsum_ref(*inputs)
    if kernel == "cox_coord":
        return ref.cox_coord_ref(*inputs)
    if kernel == "cox_batch":
        return ref.cox_batch_ref(*inputs)
    if kernel == "lipschitz":
        return ref.lipschitz_ref(*inputs)
    if kernel == "survival_curves_strat":
        return ref.survival_curves_stratified_ref(*inputs)
    return ref.survival_curves_ref(*inputs)


@pytest.mark.parametrize("kernel", sorted(autotune.CANDIDATES))
def test_every_candidate_matches_ref_on_ragged_shapes(kernel):
    """All candidates (pruned or not — blocks larger than the shape stress
    the padding paths) agree with the oracle at a ragged shape."""
    shape = RAGGED_SHAPES[kernel]
    inputs = autotune._build_inputs(kernel, shape, seed=3)
    expect = [np.asarray(a, np.float32)
              for a in jax.tree_util.tree_leaves(_reference(kernel, inputs))]
    configs = [autotune.DEFAULT_CONFIGS[kernel]] + autotune.CANDIDATES[kernel]
    seen = []
    for cfg in configs:
        if cfg in seen:
            continue
        seen.append(cfg)
        got = autotune.run_config(kernel, inputs, cfg, interpret=True)
        leaves = [np.asarray(a, np.float32)
                  for a in jax.tree_util.tree_leaves(got)]
        assert len(leaves) == len(expect)
        for a, b in zip(leaves, expect):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                       err_msg=f"{kernel} {cfg}")


def test_tuned_winner_matches_ref_end_to_end(tmp_path):
    """autotune -> cache -> lookup -> run at the winning config == oracle."""
    path = str(tmp_path / "tuned.json")
    shape = {"b": 48, "g": 20}
    cfg = autotune.autotune("survival_curves", shape, cache_file=path,
                            reps=1)
    inputs = autotune._build_inputs("survival_curves", shape, seed=11)
    got = autotune.run_config("survival_curves", inputs, cfg, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.survival_curves_ref(*inputs)),
                               rtol=2e-5, atol=2e-5)
