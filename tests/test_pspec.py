"""pspec.constrain contract + the models/compat mesh-probe seam.

The regression class under test: JAX 0.4.37 has no public
``jax.sharding.get_abstract_mesh``, and the raw call killed all 41
model-zoo tests with one AttributeError. The seam must (a) no-op without
a mesh, (b) resolve through whichever probe this JAX version has, and
(c) keep working when the public probe disappears again.
"""
import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.models import compat, pspec


# -- resolve_spec: pure resolution logic (no mesh required) -----------------

NAMES = ("data", "model")
SIZES = (("data", 4), ("model", 2))
POD_NAMES = ("pod", "data", "model")
POD_SIZES = (("pod", 2), ("data", 4), ("model", 2))


def test_resolve_dp_without_pod_axis():
    spec = pspec.resolve_spec(("dp", None, "model"), (8, 16, 64),
                              NAMES, SIZES)
    assert spec == (("data",), None, "model")


def test_resolve_dp_with_pod_axis():
    spec = pspec.resolve_spec(("dp", None, "model"), (8, 16, 64),
                              POD_NAMES, POD_SIZES)
    assert spec == (("pod", "data"), None, "model")


def test_resolve_dp_include_model_knob():
    spec = pspec.resolve_spec(("dp",), (16,), NAMES, SIZES,
                              dp_include_model=True)
    assert spec == ((("data", "model")),)


def test_resolve_divisibility_fallback_to_none():
    # batch 6 is not divisible by pod*data=8, d_model 65 not by model=2
    spec = pspec.resolve_spec(("dp", None, "model"), (6, 16, 65),
                              POD_NAMES, POD_SIZES)
    assert spec == (None, None, None)


def test_resolve_unknown_axis_is_replicated():
    spec = pspec.resolve_spec(("expert",), (8,), NAMES, SIZES)
    assert spec == (None,)


# -- constrain: ambient-mesh behavior ---------------------------------------

def test_constrain_no_mesh_is_identity():
    x = jnp.ones((4, 8))
    assert pspec.constrain(x, "dp", None) is x


def test_constrain_no_mesh_inside_jit():
    @jax.jit
    def f(x):
        return pspec.constrain(x, "dp", None, "model") * 2.0

    out = f(jnp.ones((2, 3, 4)))
    np.testing.assert_array_equal(np.asarray(out), 2.0)


def test_constrain_under_ambient_mesh():
    """With a real 1-device mesh ambient, constrain must go through
    with_sharding_constraint (and stay numerically a no-op)."""
    from repro.launch.mesh import mesh_context, make_host_mesh
    mesh = make_host_mesh()
    x = jnp.arange(8.0).reshape(4, 2)
    with mesh_context(mesh):
        am = compat.get_abstract_mesh()
        assert am is not None
        assert set(("data", "model")) <= set(am.axis_names)
        y = pspec.constrain(x, "dp", "model")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# -- compat seam: probe order + missing-API regression ----------------------

def test_compat_returns_none_outside_any_mesh():
    assert compat.get_abstract_mesh() is None


def test_compat_missing_get_abstract_mesh_regression(monkeypatch):
    """The 0.4.37 break: jax.sharding has no get_abstract_mesh. The seam
    must resolve via the thread-resources physical mesh, not raise."""
    monkeypatch.setattr(compat, "_PUBLIC_PROBE", None)
    from repro.launch.mesh import mesh_context, make_host_mesh
    assert compat.get_abstract_mesh() is None       # still no mesh -> None
    with mesh_context(make_host_mesh()):
        am = compat.get_abstract_mesh()
        assert am is not None
        assert dict(zip(am.axis_names, am.axis_sizes)) == {"data": 1,
                                                           "model": 1}


def test_compat_prefers_public_probe(monkeypatch):
    """When a public probe exists it wins over the physical fallback."""

    class FakeMesh:
        axis_names = ("pod", "data")
        axis_sizes = (2, 8)

    am = compat.get_abstract_mesh(probe=lambda: FakeMesh())
    assert am.axis_names == ("pod", "data")


def test_compat_empty_abstract_mesh_falls_through():
    """A probe returning an unset/empty mesh (0.4.x private API returns
    ``()``) must fall through to the physical mesh, not be trusted."""
    assert compat.get_abstract_mesh(probe=lambda: ()) is None
    from repro.launch.mesh import mesh_context, make_host_mesh
    with mesh_context(make_host_mesh()):
        am = compat.get_abstract_mesh(probe=lambda: ())
        assert am is not None and "data" in am.axis_names


def test_compat_probe_raising_attributeerror_is_survivable():
    def broken():
        raise AttributeError("module 'jax.sharding' has no attribute ...")

    assert compat.get_abstract_mesh(probe=broken) is None


def test_mesh_probe_status_shape():
    st = compat.mesh_probe_status()
    assert st["probe"] in ("abstract", "physical-fallback")
    assert st["ambient_axes"] == ()
    assert isinstance(st["jax_floor"], str)


def test_constrain_resolves_pod_dp_spec():
    """End-to-end: a fake ambient mesh with a pod axis resolves "dp" to
    ("pod","data") and divisibility gates each dim independently."""

    class FakeMesh:
        axis_names = ("pod", "data")
        axis_sizes = (2, 2)

    captured = {}

    def fake_constrain(x, spec):
        captured["spec"] = spec
        return x

    orig_mesh, orig_wsc = pspec._mesh, jax.lax.with_sharding_constraint
    pspec._mesh = lambda: FakeMesh()
    jax.lax.with_sharding_constraint = fake_constrain
    try:
        pspec.constrain(jnp.ones((8, 5)), "dp", "data")
    finally:
        pspec._mesh = orig_mesh
        jax.lax.with_sharding_constraint = orig_wsc
    # dim0: 8 % (2*2) == 0 -> ("pod","data"); dim1: 5 % 2 != 0 -> None
    assert tuple(captured["spec"]) == (("pod", "data"), None)
