"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cox
from repro.kernels import ops, ref
from repro.kernels.cox_batch import cox_batch
from repro.kernels.cox_coord import cox_coord
from repro.kernels.revcumsum import revcumsum


def _rand(shape, dtype, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype=dtype)


@pytest.mark.parametrize("n", [1, 7, 128, 513, 1000, 4096])
@pytest.mark.parametrize("m", [1, 3, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_revcumsum_matches_ref(n, m, dtype):
    x = _rand((n, m), dtype, seed=n + m)
    out = revcumsum(x, block_n=256, interpret=True)
    expect = ref.revcumsum_ref(x)
    # blocked-matmul vs sequential-scan accumulation order differs -> allow
    # summation noise proportional to sqrt(n)
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [5, 64, 257, 1024, 3000])
@pytest.mark.parametrize("block", [128, 1024])
@pytest.mark.parametrize("order", [2, 3])
def test_cox_coord_matches_ref(n, block, order):
    rng = np.random.default_rng(n + order)
    eta = jnp.asarray(rng.standard_normal(n) * 0.8, jnp.float32)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    d = jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32))
    g, h, c3 = cox_coord(eta, x, d, order=order, block=block, interpret=True)
    g_r, h_r, c3_r = ref.cox_coord_ref(eta, x, d, order=order)
    np.testing.assert_allclose(g, g_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h, h_r, rtol=2e-5, atol=2e-5)
    if order >= 3:
        np.testing.assert_allclose(c3, c3_r, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,p", [(64, 8), (500, 33), (1024, 256), (2050, 70)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cox_batch_matches_ref(n, p, dtype):
    rng = np.random.default_rng(n + p)
    x = jnp.asarray(rng.standard_normal((n, p)), dtype)
    eta = jnp.asarray(rng.standard_normal(n) * 0.5, jnp.float32)
    d = jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32))
    w = jnp.exp(eta - jnp.max(eta))
    s0 = jax.lax.cumsum(w, axis=0, reverse=True)
    inv_s0 = 1.0 / s0
    a = jnp.cumsum(d * inv_s0)
    wa = w * a
    r = wa - d
    g, h = cox_batch(x, w, r, wa, d, inv_s0, block_n=256, block_p=128,
                     interpret=True)
    g_r, h_r = ref.cox_batch_ref(x, w, r, wa, d, inv_s0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(g, g_r, rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(h, h_r, rtol=tol, atol=tol * 10)


def test_ops_against_core_no_ties():
    """End-to-end: kernel wrappers agree with core.cox on tie-free data."""
    rng = np.random.default_rng(0)
    n, p = 400, 12
    x = rng.standard_normal((n, p)).astype(np.float32)
    t = rng.uniform(1.0, 2.0, size=n).astype(np.float32)  # continuous: no ties
    assert len(np.unique(t)) == n
    delta = (rng.uniform(size=n) < 0.6).astype(np.float32)
    data = cox.prepare(x, t, delta)
    beta = jnp.asarray(rng.standard_normal(p).astype(np.float32) * 0.3)
    eta = data.x @ beta

    g_all, h_all = ops.cox_batch_grad_hess(eta, data.x, data.delta)
    g_core, h_core = cox.grad_hess_all(data, eta)
    np.testing.assert_allclose(g_all, g_core, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_all, h_core, rtol=2e-4, atol=2e-4)

    for l in [0, 5, 11]:
        g, h = ops.cox_coord_grad_hess(eta, data.x[:, l], data.delta)
        g_c, h_c, _ = cox.coord_derivs(data, eta, data.x[:, l])
        np.testing.assert_allclose(g, g_c, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(h, h_c, rtol=2e-4, atol=2e-4)


def test_revcumsum_ops_1d():
    x = _rand((777,), jnp.float32, seed=9)
    np.testing.assert_allclose(ops.revcumsum(x), ref.revcumsum_ref(x),
                               rtol=1e-5, atol=1e-5)


def test_fit_cd_with_pallas_kernel_path():
    """End-to-end: the fused-kernel CD (interpret mode) walks the same
    trajectory as the jnp CD on tie-free data — the paper's solver with the
    TPU fast path engaged."""
    from repro.core import solvers

    rng = np.random.default_rng(7)
    n, p = 300, 10
    x = rng.standard_normal((n, p)).astype(np.float32)
    t = rng.uniform(1.0, 2.0, size=n).astype(np.float32)
    assert len(np.unique(t)) == n
    delta = (rng.uniform(size=n) < 0.6).astype(np.float32)
    data = cox.prepare(x, t, delta)
    for method in ("cd_quad", "cd_cubic"):
        res_k = solvers.fit_cd(data, lam1=0.5, lam2=0.5, n_iters=8,
                               method=method, use_kernel=True)
        res_j = solvers.fit_cd(data, lam1=0.5, lam2=0.5, n_iters=8,
                               method=method, use_kernel=False)
        np.testing.assert_allclose(np.asarray(res_k.objective),
                                   np.asarray(res_j.objective),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(res_k.beta),
                                   np.asarray(res_j.beta),
                                   rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("n,m", [(50, 4), (513, 8), (2000, 16)])
def test_lipschitz_kernel_matches_core(n, m):
    """Pallas Lipschitz constants == core.cox.lipschitz_constants on
    tie-free sorted data (sweep shapes incl. non-multiple-of-block n)."""
    rng = np.random.default_rng(n + m)
    x = rng.standard_normal((n, m)).astype(np.float32)
    # distinct-by-construction times (f32 uniform draws collide at n=2000)
    t = rng.permutation(1.0 + np.arange(n) / n).astype(np.float32)
    assert len(np.unique(t)) == n
    delta = (rng.uniform(size=n) < 0.6).astype(np.float32)
    data = cox.prepare(x, t, delta)
    l2_ref, l3_ref = cox.lipschitz_constants(data)
    l2_k, l3_k = ops.lipschitz_constants(data.x, data.delta, block_n=256)
    np.testing.assert_allclose(l2_k, l2_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(l3_k, l3_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,g", [(1, 1, 16), (37, 5, 200), (64, 3, 128),
                                   (130, 8, 257)])
def test_survival_curves_stratified_matches_ref(b, s, g):
    """Scalar-prefetch baseline-row gather == jnp oracle (interpret mode)."""
    from repro.kernels.survival_curves import survival_curves_stratified

    rng = np.random.default_rng(b + s + g)
    eta = jnp.asarray(rng.standard_normal(b).astype(np.float32))
    h0 = jnp.asarray(np.cumsum(rng.uniform(0, 0.05, (s, g)),
                               axis=1).astype(np.float32))
    strata = jnp.asarray(rng.integers(0, s, b).astype(np.int32))
    out = survival_curves_stratified(eta, h0, strata, block_g=128,
                                     interpret=True)
    want = ref.survival_curves_stratified_ref(eta, h0, strata)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_survival_curves_stratified_clips_extreme_eta():
    from repro.kernels.survival_curves import survival_curves_stratified

    eta = jnp.asarray([100.0, -100.0], jnp.float32)
    h0 = jnp.asarray(np.linspace(0.0, 2.0, 32, dtype=np.float32))[None, :]
    strata = jnp.zeros(2, jnp.int32)
    out = np.asarray(survival_curves_stratified(eta, h0, strata,
                                                interpret=True))
    want = np.asarray(ref.survival_curves_stratified_ref(eta, h0, strata))
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)
    assert np.all(np.isfinite(out))


def test_ops_stratified_dispatch_matches_ref():
    """ops-level dispatch (autotune lookup path) agrees with the oracle."""
    rng = np.random.default_rng(99)
    b, s, g = 25, 4, 64
    eta = jnp.asarray(rng.standard_normal(b).astype(np.float32))
    h0 = jnp.asarray(np.cumsum(rng.uniform(0, 0.05, (s, g)),
                               axis=1).astype(np.float32))
    strata = jnp.asarray(rng.integers(0, s, b).astype(np.int32))
    out = ops.survival_curves_stratified(eta, h0, strata)
    want = ref.survival_curves_stratified_ref(eta, h0, strata)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
