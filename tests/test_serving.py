"""Serving subsystem: Breslow artifact parity with the numpy evaluation
path, save/load round trips, sparse fast path, the fused curve kernel, and
the continuous-batching service (including overload shedding, wait
deadlines, and concurrent submit/step/stats)."""
import threading

import numpy as np
import pytest

from repro.data.synthetic import make_tied_survival
from repro.kernels import ops, ref
from repro.kernels.survival_curves import survival_curves
from repro.serving import (QueueFull, RiskService, ScoreTimeout,
                           ScoringEngine, SurvivalModel,
                           fit_survival_model)
from repro.survival import metrics


def _problem(n=200, p=8, seed=0, ties=True):
    if ties:
        x, t, delta = make_tied_survival(n=n, p=p, seed=seed)
    else:
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((n, p)).astype(np.float32)
        t = rng.permutation(1.0 + np.arange(n) / n).astype(np.float32)
        delta = (rng.uniform(size=n) < 0.7).astype(np.float32)
    rng = np.random.default_rng(seed + 1)
    beta = (rng.standard_normal(p) * 0.4).astype(np.float32)
    return x, t, delta, beta


# ---------------------------------------------------------------------------
# Breslow baseline: JAX artifact vs numpy survival/metrics.py estimator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ties", [True, False])
def test_breslow_artifact_matches_numpy(ties):
    x, t, delta, beta = _problem(ties=ties)
    model = fit_survival_model(x, t, delta, beta)
    h = metrics.breslow_baseline(t, delta, x @ beta)
    np.testing.assert_allclose(model.base_cumhaz[0], h(model.time_grid),
                               rtol=1e-4, atol=1e-6)


def test_breslow_artifact_stratified_matches_per_stratum_numpy():
    x, t, delta, beta = _problem(n=240)
    rng = np.random.default_rng(7)
    strata = rng.integers(0, 3, size=len(t))
    model = fit_survival_model(x, t, delta, beta, strata=strata)
    assert model.n_strata == 3
    eta = x @ beta
    for s in range(3):
        m = strata == s
        h = metrics.breslow_baseline(t[m], delta[m], eta[m])
        np.testing.assert_allclose(model.base_cumhaz[s],
                                   h(model.time_grid),
                                   rtol=1e-4, atol=1e-6)


def test_efron_equals_breslow_without_ties():
    x, t, delta, beta = _problem(ties=False)
    mb = fit_survival_model(x, t, delta, beta, ties="breslow")
    me = fit_survival_model(x, t, delta, beta, ties="efron")
    np.testing.assert_allclose(me.base_cumhaz, mb.base_cumhaz,
                               rtol=1e-5, atol=1e-7)


def test_efron_baseline_smaller_increments_with_ties():
    """Efron's shrunk risk sets give H0 >= Breslow's at every grid point
    (1/(S0 - c) >= 1/S0), strictly somewhere on heavily tied data."""
    x, t, delta, beta = _problem(ties=True)
    mb = fit_survival_model(x, t, delta, beta, ties="breslow")
    me = fit_survival_model(x, t, delta, beta, ties="efron")
    assert np.all(me.base_cumhaz >= mb.base_cumhaz - 1e-7)
    assert np.any(me.base_cumhaz > mb.base_cumhaz + 1e-6)


# ---------------------------------------------------------------------------
# Round trips (acceptance: bitwise identical curves after save -> load)
# ---------------------------------------------------------------------------

def _roundtrip_model(model, tmp_path, tag):
    path = model.save(str(tmp_path / f"model_{tag}"))
    return SurvivalModel.load(path)


def test_roundtrip_bitwise_dense_sparse_stratified(tmp_path):
    x, t, delta, beta = _problem(n=160, p=12)
    rng = np.random.default_rng(3)
    strata = rng.integers(0, 2, size=len(t))
    beta_sparse = np.zeros_like(beta)
    beta_sparse[[2, 7]] = beta[[2, 7]]
    cases = {
        "dense": (fit_survival_model(x, t, delta, beta), None),
        "sparse": (fit_survival_model(x, t, delta, beta_sparse), None),
        "strat": (fit_survival_model(x, t, delta, beta, strata=strata),
                  strata[:16].astype(np.int32)),
    }
    q = x[:16]
    for tag, (model, s) in cases.items():
        loaded = _roundtrip_model(model, tmp_path, tag)
        for field in ("beta", "time_grid", "base_cumhaz"):
            np.testing.assert_array_equal(getattr(model, field),
                                          getattr(loaded, field), err_msg=tag)
        assert loaded.ties == model.ties
        if model.support is not None:
            np.testing.assert_array_equal(model.support, loaded.support)
        c0 = ScoringEngine(model).survival_curves(q, strata=s)
        c1 = ScoringEngine(loaded).survival_curves(q, strata=s)
        np.testing.assert_array_equal(c0, c1, err_msg=tag)


# ---------------------------------------------------------------------------
# Engine: sparse fast path, curve formula, median, bucketing
# ---------------------------------------------------------------------------

def test_engine_sparse_matches_dense_path():
    x, t, delta, beta = _problem(n=150, p=40)
    beta_s = np.zeros(40, np.float32)
    beta_s[[3, 17, 31]] = (0.5, -0.8, 0.3)
    model = fit_survival_model(x, t, delta, beta_s)
    assert model.k == 3
    q = np.random.default_rng(0).standard_normal((33, 40)).astype(np.float32)
    dense = ScoringEngine(model, use_sparse=False)
    sparse = ScoringEngine(model, use_sparse=True)
    np.testing.assert_allclose(sparse.risk_scores(q), dense.risk_scores(q),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(sparse.survival_curves(q),
                               dense.survival_curves(q),
                               rtol=1e-5, atol=1e-6)
    # pre-gathered (b, k) features hit the same path
    qk = q[:, model.support]
    np.testing.assert_array_equal(sparse.risk_scores(qk),
                                  sparse.risk_scores(q))


def test_engine_curves_match_closed_form():
    x, t, delta, beta = _problem()
    model = fit_survival_model(x, t, delta, beta)
    q = x[:10]
    eta = np.clip(q @ beta, -30, 30)
    expect = np.exp(-model.base_cumhaz[0][None, :]
                    * np.exp(eta)[:, None])
    got = ScoringEngine(model).survival_curves(q)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)
    # curves are nonincreasing in t and start near S(0) = 1
    assert np.all(np.diff(got, axis=1) <= 1e-7)


def test_engine_median_survival():
    x, t, delta, beta = _problem()
    model = fit_survival_model(x, t, delta, beta)
    eng = ScoringEngine(model)
    q = x[:20]
    med = eng.median_survival(q)
    s = eng.survival_curves(q)
    grid = model.time_grid
    for i in range(len(q)):
        below = s[i] <= 0.5
        if below.any():
            assert med[i] == grid[np.argmax(below)]
        else:
            assert np.isinf(med[i])


def test_engine_bucketed_jit_cache():
    x, t, delta, beta = _problem()
    model = fit_survival_model(x, t, delta, beta)
    eng = ScoringEngine(model)
    for b in (1, 2, 3, 5, 7, 9, 15, 17, 31, 33):
        eng.risk_scores(x[:b])
    # 10 distinct batch sizes collapse into pow2 buckets 1..64 -> <= 7
    assert eng.cache_info()["entries"] <= 7


# ---------------------------------------------------------------------------
# Fused Pallas curve kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,g", [(1, 1), (7, 33), (256, 128), (300, 130)])
def test_survival_curves_kernel_matches_ref(b, g):
    rng = np.random.default_rng(b + g)
    eta = rng.standard_normal(b).astype(np.float32) * 2.0
    h0 = np.sort(rng.uniform(0, 3, g)).astype(np.float32)
    out = survival_curves(eta, h0, block_b=128, block_g=64, interpret=True)
    expect = ref.survival_curves_ref(eta, h0)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_survival_curves_kernel_extreme_eta_saturates():
    eta = np.asarray([-80.0, 80.0], np.float32)
    h0 = np.asarray([0.5, 1.0], np.float32)
    out = np.asarray(ops.survival_curves(eta, h0))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out[0], 1.0, atol=1e-6)   # ~zero risk
    np.testing.assert_allclose(out[1], 0.0, atol=1e-6)   # huge risk


# ---------------------------------------------------------------------------
# Service: continuous batching
# ---------------------------------------------------------------------------

def test_service_scores_match_engine_and_buckets():
    x, t, delta, beta = _problem(n=180, p=8)
    model = fit_survival_model(x, t, delta, beta)
    eng = ScoringEngine(model)
    svc = RiskService(eng, max_batch=16, return_curves=True)
    rids = [svc.submit(x[i]) for i in range(50)]
    served = svc.drain()
    assert served == 50
    risks = eng.risk_scores(x[:50])
    meds = eng.median_survival(x[:50])
    for i, rid in enumerate(rids):
        resp = svc.result(rid)
        assert resp is not None
        np.testing.assert_allclose(resp.risk, risks[i], rtol=1e-6)
        assert resp.median == meds[i] or (np.isinf(resp.median)
                                          and np.isinf(meds[i]))
        assert resp.curve is not None and resp.curve.shape == (128,)
        assert resp.latency_s >= 0.0
    st = svc.stats()
    assert st["n_requests"] == 50
    assert st["n_batches"] >= 4          # 50 reqs / max_batch 16
    assert st["latency_p99_ms"] >= st["latency_p50_ms"]


def test_service_background_thread():
    x, t, delta, beta = _problem(n=120, p=6)
    model = fit_survival_model(x, t, delta, beta)
    svc = RiskService(ScoringEngine(model), max_batch=8)
    svc.start()
    try:
        rids = [svc.submit(x[i]) for i in range(20)]
        outs = [svc.wait(rid, timeout=60.0) for rid in rids]
    finally:
        svc.stop()
    assert len(outs) == 20
    assert all(np.isfinite(o.risk) for o in outs)


def test_service_stratified_requests():
    x, t, delta, beta = _problem(n=200, p=8)
    rng = np.random.default_rng(11)
    strata = rng.integers(0, 2, size=len(t))
    model = fit_survival_model(x, t, delta, beta, strata=strata)
    eng = ScoringEngine(model)
    svc = RiskService(eng, max_batch=8, return_curves=True)
    r0 = svc.submit(x[0], stratum=0)
    r1 = svc.submit(x[0], stratum=1)
    svc.drain()
    c0 = svc.result(r0).curve
    c1 = svc.result(r1).curve
    # same features, different baselines -> different curves
    assert not np.allclose(c0, c1)
    expect = np.exp(-model.base_cumhaz
                    * np.exp(np.clip(x[0] @ beta, -30, 30)))
    np.testing.assert_allclose(c0, expect[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c1, expect[1], rtol=1e-5, atol=1e-6)


def test_engine_fused_score_matches_individual_queries():
    x, t, delta, beta = _problem(n=150, p=8)
    model = fit_survival_model(x, t, delta, beta)
    eng = ScoringEngine(model)
    q = x[:12]
    risk, med, curves = eng.score(q, with_curves=True)
    np.testing.assert_allclose(risk, eng.risk_scores(q), rtol=1e-6)
    np.testing.assert_allclose(curves, eng.survival_curves(q), rtol=1e-6)
    m_ref = eng.median_survival(q)
    assert np.array_equal(med, m_ref) or np.allclose(
        med[np.isfinite(med)], m_ref[np.isfinite(m_ref)])
    risk2, med2 = eng.score(q, with_curves=False)
    np.testing.assert_array_equal(risk2, risk)


def test_engine_rejects_out_of_range_stratum():
    x, t, delta, beta = _problem(n=120, p=6)
    strata = np.random.default_rng(0).integers(0, 2, size=len(t))
    model = fit_survival_model(x, t, delta, beta, strata=strata)
    eng = ScoringEngine(model)
    with pytest.raises(ValueError, match="stratum"):
        eng.survival_curves(x[:4], strata=np.asarray([0, 1, 2, 0]))
    with pytest.raises(ValueError, match="stratum"):
        eng.survival_curves(x[:2], strata=np.asarray([-1, 0]))


def test_service_result_hands_over_once():
    x, t, delta, beta = _problem(n=100, p=6)
    svc = RiskService(ScoringEngine(fit_survival_model(x, t, delta, beta)),
                      max_batch=4)
    rid = svc.submit(x[0])
    svc.drain()
    assert svc.result(rid) is not None
    assert svc.result(rid) is None      # popped: no unbounded accumulation
    assert svc.stats()["n_requests"] == 1


def test_artifact_save_overwrite_never_leaves_hole(tmp_path):
    x, t, delta, beta = _problem(n=80, p=6)
    model = fit_survival_model(x, t, delta, beta)
    path = model.save(str(tmp_path / "m"))
    loaded1 = SurvivalModel.load(path)
    path = model.save(str(tmp_path / "m"))      # overwrite in place
    loaded2 = SurvivalModel.load(path)
    np.testing.assert_array_equal(loaded1.base_cumhaz, loaded2.base_cumhaz)
    assert not (tmp_path / "m.old").exists()
    assert not (tmp_path / "m.tmp").exists()


def test_stats_keys_present_on_fresh_service():
    """Dashboards must not key-error before the first request: every
    stats() key exists (percentiles 0.0, throughput NaN) on an idle
    service."""
    x, t, delta, beta = _problem(n=80, p=6)
    svc = RiskService(ScoringEngine(fit_survival_model(x, t, delta, beta)))
    st = svc.stats()
    for key in ("n_requests", "wall_s", "reqs_per_s", "n_batches",
                "mean_batch", "queue_depth", "rejected_count",
                "timeout_count", "latency_p50_ms", "latency_p99_ms",
                "engine"):
        assert key in st, key
    assert st["n_requests"] == 0
    assert st["queue_depth"] == 0
    assert st["rejected_count"] == 0
    assert st["latency_p50_ms"] == 0.0
    assert st["latency_p99_ms"] == 0.0
    assert np.isnan(st["reqs_per_s"])


def test_wait_timeout_raises_score_timeout_and_abandons():
    x, t, delta, beta = _problem(n=80, p=6)
    svc = RiskService(ScoringEngine(fit_survival_model(x, t, delta, beta)))
    rid = svc.submit(x[0])          # never stepped: no serving thread
    with pytest.raises(ScoreTimeout) as ei:
        svc.wait(rid, timeout=0.05)
    assert ei.value.rid == rid
    assert str(rid) in str(ei.value)
    assert svc.stats()["timeout_count"] == 1
    # abandoned: the queued copy is dropped at batch-form time (no jit
    # work wasted) and no response accumulates for it
    assert svc.drain() == 0
    assert svc.result(rid) is None
    assert svc.stats()["results_evicted"] == 1
    assert svc.stats()["results_pending"] == 0


def test_bounded_queue_sheds_with_queue_full():
    x, t, delta, beta = _problem(n=80, p=6)
    svc = RiskService(ScoringEngine(fit_survival_model(x, t, delta, beta)),
                      max_queue=2)
    svc.submit(x[0])
    svc.submit(x[1])
    with pytest.raises(QueueFull):
        svc.submit(x[2])
    st = svc.stats()
    assert st["rejected_count"] == 1
    assert st["queue_depth"] == 2
    assert svc.drain() == 2         # shed request never enters a batch


def test_concurrent_submit_step_stats():
    """Producers, the serving thread, and a stats poller all hammering the
    service concurrently: every request is scored exactly once and the
    counters reconcile."""
    x, t, delta, beta = _problem(n=200, p=8)
    svc = RiskService(ScoringEngine(fit_survival_model(x, t, delta, beta)),
                      max_batch=16)
    svc.start()
    n_threads, per_thread = 4, 25
    rids = [[] for _ in range(n_threads)]
    stats_seen = []
    stop_polling = threading.Event()

    def produce(slot):
        rng = np.random.default_rng(slot)
        for _ in range(per_thread):
            rids[slot].append(
                svc.submit(rng.standard_normal(8).astype(np.float32)))

    def poll():
        while not stop_polling.is_set():
            stats_seen.append(svc.stats())

    threads = [threading.Thread(target=produce, args=(s,))
               for s in range(n_threads)]
    poller = threading.Thread(target=poll)
    poller.start()
    try:
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        outs = [svc.wait(rid, timeout=60.0)
                for slot in rids for rid in slot]
    finally:
        stop_polling.set()
        poller.join()
        svc.stop()
    total = n_threads * per_thread
    assert len(outs) == total
    assert all(np.isfinite(o.risk) for o in outs)
    # rids are unique and each response matches its request id
    flat = [rid for slot in rids for rid in slot]
    assert len(set(flat)) == total
    assert [o.rid for o in outs] == flat
    st = svc.stats()
    assert st["n_requests"] == total
    assert st["timeout_count"] == 0 and st["rejected_count"] == 0
    assert st["queue_depth"] == 0
    # stats() stayed coherent mid-flight: monotone n_requests, all keys
    assert stats_seen, "poller never ran"
    served_seq = [s["n_requests"] for s in stats_seen]
    assert served_seq == sorted(served_seq)
    assert all("latency_p99_ms" in s for s in stats_seen)


# ---------------------------------------------------------------------------
# Satellite: chunked cindex parity
# ---------------------------------------------------------------------------

def test_cindex_chunked_matches_full_broadcast():
    rng = np.random.default_rng(5)
    n = 500
    t = rng.uniform(0, 2, n)
    t[::7] = t[1::7][: len(t[::7])]      # inject time ties
    delta = (rng.uniform(size=n) < 0.6).astype(float)
    risk = rng.standard_normal(n)
    risk[::5] = risk[1::5][: len(risk[::5])]  # and risk ties
    # oracle: the original single-shot broadcast
    comparable = (t[:, None] < t[None, :]) & (delta[:, None] > 0)
    conc = (risk[:, None] > risk[None, :]) & comparable
    ties = np.isclose(risk[:, None], risk[None, :]) & comparable
    expect = (conc.sum() + 0.5 * ties.sum()) / comparable.sum()
    for chunk in (1, 17, 100, 4096):
        assert metrics.cindex(t, delta, risk, chunk=chunk) == expect
