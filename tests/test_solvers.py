"""Solver behaviour: monotone decrease (the paper's headline guarantee),
agreement of every convergent method on the same convex optimum, and the
early-stopping variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cox, solvers
from repro.data.synthetic import SyntheticSpec, make_correlated_survival, \
    make_tied_survival

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    x, t, delta, _ = make_correlated_survival(
        SyntheticSpec(n=300, p=20, k=4, rho=0.7, seed=2))
    return cox.prepare(x.astype(np.float64), t, delta)


def test_cd_monotone_decrease(problem):
    for method in ("cd_quad", "cd_cubic"):
        res = solvers.fit_cd(problem, lam1=0.0, lam2=0.1, n_iters=30,
                             method=method)
        obj = np.asarray(res.objective)
        assert np.all(np.diff(obj) <= 1e-9), method
        assert np.all(np.isfinite(obj)), method


def test_cd_monotone_decrease_l1(problem):
    for method in ("cd_quad", "cd_cubic"):
        res = solvers.fit_cd(problem, lam1=1.0, lam2=1.0, n_iters=30,
                             method=method)
        obj = np.asarray(res.objective)
        assert np.all(np.diff(obj) <= 1e-9), method
        assert np.all(np.isfinite(obj)), method


def test_all_solvers_reach_same_smooth_optimum(problem):
    """lam2 > 0 -> strongly convex, unique optimum; every convergent method
    must agree. newton_ls is the high-precision reference."""
    ref = solvers.fit_newton(problem, lam2=1.0, n_iters=40, line_search=True)
    f_ref = float(ref.objective[-1])
    for name in ("cd_quad", "cd_cubic", "quasi_newton", "prox_newton"):
        res = solvers.SOLVERS[name](problem, 0.0, 1.0, 400)
        assert float(res.objective[-1]) <= f_ref + 1e-6, (
            name, float(res.objective[-1]), f_ref)


def test_cd_l1_matches_prox_newton_optimum(problem):
    """Same convex l1+l2 objective -> same optimal value across methods."""
    r1 = solvers.fit_cd(problem, lam1=1.0, lam2=1.0, n_iters=500,
                        method="cd_quad")
    r2 = solvers.fit_cd(problem, lam1=1.0, lam2=1.0, n_iters=500,
                        method="cd_cubic")
    r3 = solvers.fit_working_newton(problem, lam1=1.0, lam2=1.0, n_iters=200,
                                    variant="prox")
    f1, f2, f3 = (float(r.objective[-1]) for r in (r1, r2, r3))
    assert abs(f1 - f2) < 1e-6
    assert f1 <= f3 + 1e-5


def test_cubic_converges_faster_per_iteration(problem):
    """2nd-order surrogate uses curvature -> at least as good per sweep."""
    rq = solvers.fit_cd(problem, lam2=0.1, n_iters=25, method="cd_quad")
    rc = solvers.fit_cd(problem, lam2=0.1, n_iters=25, method="cd_cubic")
    assert float(rc.objective[-1]) <= float(rq.objective[-1]) + 1e-8


def test_fit_cd_tol_early_stops(problem):
    res = solvers.fit_cd_tol(problem, lam2=1.0, max_iters=500, tol=1e-9)
    assert int(res.n_iters) < 500
    ref = solvers.fit_newton(problem, lam2=1.0, n_iters=40, line_search=True)
    assert float(res.objective[-1]) <= float(ref.objective[-1]) + 1e-5


def test_exact_newton_blows_up_without_line_search():
    """Reproduces the paper's critical-flaw demonstration (Fig. 1a): from
    beta=0 with weak regularization, the pure Newton step overshoots and the
    loss explodes / fails to decrease monotonically, while CD stays
    monotone on the same problem."""
    rng = np.random.default_rng(1)
    n, p = 120, 4
    # rare, heavy-tailed features: risk-set variance (the 2nd partial) is
    # tiny at beta=0 while the gradient is O(1) -> the raw Newton step
    # overshoots into the loss's linear tail and explodes.
    x = ((rng.uniform(size=(n, p)) < 0.04)
         * rng.lognormal(1.5, 1.0, size=(n, p))).astype(np.float64)
    risk = np.clip(x @ np.array([3.0, -3.0, 2.0, -2.0]), -30, 30)
    t = (-np.log(rng.uniform(1e-12, 1, n)) / np.exp(risk)) ** 0.3
    delta = (rng.uniform(size=n) < 0.8).astype(np.float64)
    data = cox.prepare(x, t, delta)
    res = solvers.fit_newton(data, lam2=0.0, n_iters=12, line_search=False)
    obj = np.asarray(res.objective)
    bad = (~np.all(np.isfinite(obj))) or np.any(np.diff(obj) > 1e-6) or \
        float(obj[-1]) > float(obj[0])
    assert bad, "expected divergence-style behaviour from raw Newton"
    res_cd = solvers.fit_cd(data, lam2=0.0, n_iters=12, method="cd_quad")
    obj_cd = np.asarray(res_cd.objective)
    assert np.all(np.isfinite(obj_cd))
    assert np.all(np.diff(obj_cd) <= 1e-9)


def test_gd_decreases(problem):
    res = solvers.fit_gd(problem, lam1=0.5, lam2=0.5, n_iters=100)
    obj = np.asarray(res.objective)
    assert np.all(np.isfinite(obj))
    assert float(obj[-1]) < float(obj[0])
