"""Fleet-grade serving robustness, proven under deterministic fault
injection (serving/chaos.py): every injected failure — engine exception,
latency spike, corrupt artifact, queue pressure — must yield a graceful
outcome (error response, shed, or health transition) with zero silent
request loss and the drain thread still alive. Plus the registry
hot-swap lifecycle, admission-control edges, and the results-lifecycle
bounds (timeout abandon, TTL sweep)."""
import threading
import time

import numpy as np
import pytest

from repro.serving import (ArtifactCorrupt, ChaosEngine, ModelRegistry,
                           Priority, QueueFull, RiskService, ScoringEngine,
                           SurvivalModel, corrupt_artifact,
                           fit_survival_model)
from repro.serving.chaos import flood
from repro.serving.registry import LIVE, READY, UNLOADED


def _problem(n=160, p=8, seed=0, scale=0.4):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    t = rng.uniform(0.1, 2.0, n).astype(np.float32)
    delta = (rng.uniform(size=n) < 0.7).astype(np.float32)
    beta = (rng.standard_normal(p) * scale).astype(np.float32)
    return x, t, delta, beta


def _model(seed=0, scale=0.4, p=8):
    x, t, delta, beta = _problem(seed=seed, scale=scale, p=p)
    return x, fit_survival_model(x, t, delta, beta)


# ---------------------------------------------------------------------------
# Admission control: deadlines, priorities, shed-low-first
# ---------------------------------------------------------------------------

def test_deadline_expired_dropped_at_batch_form():
    x, model = _model()
    svc = RiskService(ScoringEngine(model), max_batch=8)
    live = svc.submit(x[0])                       # no deadline
    dead = svc.submit(x[1], deadline_s=0.0)       # already expired
    time.sleep(0.005)
    assert svc.drain() == 1                       # only the live one scored
    assert svc.result(live).ok
    resp = svc.result(dead)
    assert resp is not None and resp.error == "deadline_exceeded"
    st = svc.stats()
    assert st["expired_count"] == 1
    assert st["n_requests"] == 1                  # expired never dispatched


def test_high_priority_dequeued_first():
    x, model = _model()
    eng = ScoringEngine(model)
    svc = RiskService(eng, max_batch=2)
    lows = [svc.submit(x[i], priority=Priority.LOW) for i in range(4)]
    high = svc.submit(x[4], priority=Priority.HIGH)
    assert svc.step() == 2
    # the first batch is the HIGH request + the oldest LOW
    assert svc.result(high) is not None
    assert svc.result(lows[0]) is not None
    assert all(svc.result(r) is None for r in lows[1:])
    svc.drain()


def test_shed_low_first_eviction_wakes_low_waiter():
    x, model = _model()
    svc = RiskService(ScoringEngine(model), max_batch=8, max_queue=2)
    lo1 = svc.submit(x[0], priority=Priority.LOW)
    lo2 = svc.submit(x[1], priority=Priority.LOW)
    hi = svc.submit(x[2], priority=Priority.HIGH)   # evicts newest LOW
    shed = svc.result(lo2)
    assert shed is not None and shed.error == "shed"
    hi2 = svc.submit(x[3], priority=Priority.HIGH)  # evicts the last LOW
    assert svc.result(lo1).error == "shed"
    # a HIGH submit at a queue full of HIGH work cannot evict -> QueueFull
    with pytest.raises(QueueFull):
        svc.submit(x[4], priority=Priority.HIGH)
    assert svc.drain() == 2                          # the two HIGHs
    assert svc.result(hi).ok and svc.result(hi2).ok
    st = svc.stats()
    assert st["shed_count"] == 2 and st["rejected_count"] == 1


def test_queue_pressure_concurrent_submitters_reconcile():
    """QueueFull + priority shedding under concurrent flood: admitted +
    rejected == offered per class, every admitted rid reaches a terminal
    outcome, and zero requests vanish."""
    x, model = _model()
    svc = RiskService(ScoringEngine(model), max_batch=16, max_queue=24)
    svc.start()
    try:
        lo = flood(svc, 40, n_threads=3, priority=Priority.LOW, seed=0)
        hi = flood(svc, 40, n_threads=3, priority=Priority.HIGH, seed=9)
    finally:
        deadline = time.perf_counter() + 30.0
        while svc.stats()["queue_depth"] and time.perf_counter() < deadline:
            time.sleep(0.01)
        svc.stop()
    assert lo["admitted"] + lo["rejected"] == 120
    assert hi["admitted"] + hi["rejected"] == 120
    outcomes = {rid: svc.result(rid) for rid in lo["rids"] + hi["rids"]}
    assert all(r is not None for r in outcomes.values())   # zero silent loss
    n_ok = sum(r.ok for r in outcomes.values())
    n_shed = sum((not r.ok) and r.error == "shed"
                 for r in outcomes.values())
    st = svc.stats()
    assert n_ok == st["n_requests"]
    assert n_shed == st["shed_count"]
    assert n_ok + n_shed == lo["admitted"] + hi["admitted"]
    assert st["rejected_count"] == lo["rejected"] + hi["rejected"]
    # every shed victim was LOW (shed-low-first)
    assert all(outcomes[rid].ok for rid in hi["rids"])


# ---------------------------------------------------------------------------
# Fault injection: engine exceptions, retry/backoff, health transitions
# ---------------------------------------------------------------------------

def test_transient_engine_fault_recovers_via_retry():
    x, model = _model()
    chaos = ChaosEngine(ScoringEngine(model), seed=0)
    svc = RiskService(chaos, max_batch=8, retries=2,
                      retry_backoff_s=0.005)
    chaos.fail_next(1)
    rid = svc.submit(x[0])
    assert svc.drain() == 1               # retry absorbed the fault
    assert svc.result(rid).ok
    st = svc.stats()
    assert st["retry_count"] == 1
    assert st["engine_failures"] == 0
    assert st["health"] == "SERVING"      # recovered


def test_exhausted_retries_yield_error_responses_and_degraded():
    x, model = _model()
    chaos = ChaosEngine(ScoringEngine(model), seed=0)
    svc = RiskService(chaos, max_batch=8, retries=1,
                      retry_backoff_s=0.005, down_after=2)
    chaos.fail_next(100)
    rids = [svc.submit(x[i]) for i in range(3)]
    assert svc.drain() == 0
    for rid in rids:                      # per-request error responses
        resp = svc.result(rid)
        assert resp is not None and "EngineFault" in resp.error
    assert svc.health() == "DEGRADED"
    # a second consecutive failed batch crosses down_after -> DOWN
    rid = svc.submit(x[3])
    svc.drain()
    assert "EngineFault" in svc.result(rid).error
    assert svc.health() == "DOWN"
    # engine heals -> first good batch restores SERVING
    chaos._fail_queue = 0                 # cancel remaining scheduled
    rid = svc.submit(x[4])
    assert svc.drain() == 1
    assert svc.result(rid).ok
    assert svc.health() == "SERVING"


def test_background_thread_survives_engine_crash():
    """The drain thread must outlive a crashing engine: errors out the
    batch, stays alive, and serves again once the engine heals."""
    x, model = _model()
    chaos = ChaosEngine(ScoringEngine(model), seed=0)
    svc = RiskService(chaos, max_batch=4, retries=0,
                      retry_backoff_s=0.001)
    svc.start()
    try:
        chaos.fail_next(5)
        bad = [svc.submit(x[i]) for i in range(3)]
        bad_resps = [svc.wait(r, timeout=30.0) for r in bad]
        assert all("EngineFault" in r.error for r in bad_resps)
        assert svc.thread_alive
        chaos._fail_queue = 0             # heal
        deadline = time.perf_counter() + 30.0
        ok = None
        while time.perf_counter() < deadline:
            rid = svc.submit(x[5])
            resp = svc.wait(rid, timeout=30.0)
            if resp.ok:
                ok = resp
                break
        assert ok is not None and np.isfinite(ok.risk)
        assert svc.thread_alive
        assert svc.health() == "SERVING"
    finally:
        svc.stop()


def test_latency_spike_expires_deadlined_requests():
    """A spiked dispatch makes queued deadlines lapse; the next batch
    drops them at form time instead of scoring stale work."""
    x, model = _model()
    chaos = ChaosEngine(ScoringEngine(model), seed=0)
    svc = RiskService(chaos, max_batch=1)
    chaos.spike_next(1, dur_s=0.15)
    first = svc.submit(x[0])                          # batch 1: spiked
    tight = svc.submit(x[1], deadline_s=0.05)         # expires mid-spike
    loose = svc.submit(x[2], deadline_s=30.0)
    assert svc.drain() == 2                           # first + loose
    assert svc.result(first).ok and svc.result(loose).ok
    resp = svc.result(tight)
    assert resp is not None and resp.error == "deadline_exceeded"
    assert svc.stats()["expired_count"] == 1
    assert chaos.spikes_injected == 1


# ---------------------------------------------------------------------------
# Artifact integrity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["truncate", "flip"])
def test_corrupt_artifact_fails_loudly(tmp_path, mode):
    _, model = _model()
    path = model.save(str(tmp_path / "m"))
    SurvivalModel.load(path)                          # pristine loads
    corrupt_artifact(path, "base_cumhaz", mode=mode)
    with pytest.raises(ArtifactCorrupt, match="base_cumhaz"):
        SurvivalModel.load(path)


def test_missing_leaf_fails_loudly(tmp_path):
    _, model = _model()
    path = model.save(str(tmp_path / "m"))
    (tmp_path / "m" / "beta.npy").unlink()
    with pytest.raises(ArtifactCorrupt, match="missing leaf beta"):
        SurvivalModel.load(path)


def test_format1_manifest_without_checksums_still_loads(tmp_path):
    import json
    import os
    _, model = _model()
    path = model.save(str(tmp_path / "m"))
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format"] = 1
    for spec in manifest["arrays"].values():
        spec.pop("sha256", None)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    loaded = SurvivalModel.load(path)                 # back-compat
    np.testing.assert_array_equal(loaded.beta, model.beta)


def test_registry_rejects_corrupt_artifact_keeps_live_engine(tmp_path):
    x, model = _model()
    svc = RiskService(ScoringEngine(model), max_batch=8)
    reg = ModelRegistry(svc, prewarm_batches=(1,))
    reg.load("v1", model)
    reg.swap("v1")
    path = model.save(str(tmp_path / "v2"))
    corrupt_artifact(path, "beta", mode="truncate")
    with pytest.raises(ArtifactCorrupt):
        reg.load("v2", path)
    assert reg.get("v2").state == "failed"
    assert reg.status()["live"] == "v1"               # untouched
    rid = svc.submit(x[0])
    svc.drain()
    assert svc.result(rid).ok                         # still serving


# ---------------------------------------------------------------------------
# Registry: lifecycle, generations, hot-swap under load
# ---------------------------------------------------------------------------

def test_registry_lifecycle_and_generations():
    x, model = _model(seed=0)
    _, model2 = _model(seed=1, scale=0.8)
    svc = RiskService(ScoringEngine(model), max_batch=8)
    reg = ModelRegistry(svc, prewarm_batches=(1, 8))
    e1 = reg.load("v1", model)
    assert e1.state == READY and e1.compiles >= 1     # warmed
    assert reg.swap("v1") == 1
    assert reg.get("v1").state == LIVE
    assert reg.rollout("v2", model2) == 2
    assert reg.status()["live"] == "v2"
    assert reg.get("v1").state == UNLOADED
    assert reg.get("v1").engine is None               # jit cache dropped
    with pytest.raises(ValueError, match="live"):
        reg.unload("v2")
    with pytest.raises(KeyError):
        reg.swap("nope")
    # served scores now come from v2's coefficients
    rid = svc.submit(x[0])
    svc.drain()
    expect = ScoringEngine(model2).risk_scores(x[:1])[0]
    np.testing.assert_allclose(svc.result(rid).risk, expect, rtol=1e-6)


def test_registry_background_load_then_swap():
    _, model = _model(seed=0)
    _, model2 = _model(seed=1)
    svc = RiskService(ScoringEngine(model), max_batch=8)
    reg = ModelRegistry(svc, prewarm_batches=(1,))
    reg.load("bg", model2, block=False)
    entry = reg.wait_ready("bg", timeout=60.0)
    assert entry.state == READY
    assert reg.swap("bg") == 1
    assert svc.engine is entry.engine


def test_prewarm_compiles_buckets_ahead():
    _, model = _model()
    eng = ScoringEngine(model)
    n = eng.prewarm(batch_sizes=(1, 3, 64), kinds=("score",))
    # buckets 1, 4, 64 -> three compilations, then zero on re-warm
    assert n == 3
    assert eng.prewarm(batch_sizes=(1, 3, 64), kinds=("score",)) == 0
    before = eng.compiles
    eng.score(np.zeros((64, eng.feature_dim), np.float32))
    assert eng.compiles == before                     # live call: no compile


def test_hot_swap_under_load_drops_nothing():
    """Satellite/acceptance: swap mid-traffic; every submitted request
    resolves ok (no drops, no errors), scores flip to the new model, and
    the generation counter advances."""
    x, model = _model(seed=0)
    _, model2 = _model(seed=1, scale=0.9)
    svc = RiskService(ScoringEngine(model), max_batch=8)
    reg = ModelRegistry(svc, prewarm_batches=(1, 8))
    reg.load("v1", model)
    reg.swap("v1")
    svc.start()
    rids = []
    stop = threading.Event()

    def produce():
        rng = np.random.default_rng(0)
        while not stop.is_set():
            rids.append(svc.submit(
                rng.standard_normal(8).astype(np.float32)))
            time.sleep(0.001)

    producer = threading.Thread(target=produce)
    producer.start()
    try:
        time.sleep(0.05)
        gen = reg.rollout("v2", model2)               # swap under load
        time.sleep(0.05)
    finally:
        stop.set()
        producer.join()
        deadline = time.perf_counter() + 30.0
        while svc.stats()["queue_depth"] and time.perf_counter() < deadline:
            time.sleep(0.01)
        svc.stop()
    assert gen == 2
    responses = [svc.result(rid) for rid in rids]
    assert all(r is not None for r in responses)      # zero silent loss
    assert all(r.ok for r in responses)               # zero errors/drops
    st = svc.stats()
    assert st["n_requests"] == len(rids)
    assert st["engine_swaps"] == 2                    # v1 swap + rollout
    assert svc.health() == "SERVING"


# ---------------------------------------------------------------------------
# Results lifecycle: TTL sweep bounds a long-running service
# ---------------------------------------------------------------------------

def test_result_ttl_sweep_evicts_uncollected():
    x, model = _model()
    svc = RiskService(ScoringEngine(model), max_batch=8,
                      result_ttl_s=0.05)
    rids = [svc.submit(x[i]) for i in range(4)]
    svc.drain()
    assert svc.stats()["results_pending"] == 4
    time.sleep(0.1)
    # next step sweeps: a fresh request's batch-form triggers it
    svc._last_sweep = 0.0                 # make the sweep eligible now
    keep = svc.submit(x[5])
    svc.drain()
    st = svc.stats()
    assert st["results_evicted"] == 4
    assert all(svc.result(r) is None for r in rids)
    assert svc.result(keep).ok


def test_wait_is_condition_signaled_not_polled():
    """A waiter wakes promptly when the background loop posts the result
    — well under the loop's idle poll interval, which a sleep-poll wait
    could not beat reliably."""
    x, model = _model()
    svc = RiskService(ScoringEngine(model), max_batch=4)
    svc.submit(x[0])
    svc.drain()                           # warm the jit bucket
    svc.start(poll_s=0.5)                 # long idle poll on purpose
    try:
        t0 = time.perf_counter()
        rid = svc.submit(x[1])
        resp = svc.wait(rid, timeout=30.0)
        dt = time.perf_counter() - t0
    finally:
        svc.stop()
    assert resp.ok
    # submit notifies the loop and step notifies the waiter: end-to-end
    # must land far below the 0.5s poll interval
    assert dt < 0.4, f"wait took {dt:.3f}s - condition signaling broken?"
