"""Roofline machinery unit tests + end-to-end launcher smoke (train CLI
with checkpoint/resume, serve CLI)."""
import numpy as np

from repro.analysis import roofline as rl


def test_parse_collectives_counts_and_bytes():
    hlo = """
  %ag = f32[128,256] all-gather(%x), replica_groups={{0,1,2,3}}, dims={0}
  %ar.1 = bf16[1024] all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[64] reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[32,32] collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = bf16[8,8] all-to-all(%v), replica_groups={{0,1,2,3}}
  %ar-start = f32[10] all-reduce-start(%q), replica_groups={{0,1}}
  %ar-done = f32[10] all-reduce-done(%ar-start)
"""
    st = rl.parse_collectives(hlo)
    assert st.n_ops == 6  # -done not double counted
    ag = 128 * 256 * 4
    assert abs(st.op_bytes["all-gather"] - ag) < 1
    # ring model: all-gather moves size*(n-1)/n with n=4
    assert st.moved_bytes > 0
    # all-reduce with iota groups [16,16]<=[256]: n = 16
    assert st.op_bytes["all-reduce"] == 1024 * 2 + 10 * 4


def test_roofline_terms_and_bottleneck():
    coll = rl.CollectiveStats(op_bytes={}, moved_bytes=50e9, n_ops=1)
    r = rl.compute_roofline(197e12, 819e9, coll, 256, 197e12 * 256 * 0.5)
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 1.0) < 1e-6
    assert abs(r.collective_s - 1.0) < 1e-6
    assert r.useful_flops_ratio == 0.5
    coll2 = rl.CollectiveStats(op_bytes={}, moved_bytes=500e9, n_ops=1)
    r2 = rl.compute_roofline(1e12, 1e9, coll2, 256, 1e12)
    assert r2.bottleneck == "collective"


def test_active_params_sane():
    from repro.configs import get_config
    # deepseek-67b ~ 67B params
    n = rl.active_params(get_config("deepseek-67b"))
    assert 6.0e10 < n < 7.5e10, n
    # mixtral-8x7b active (top-2 of 8): ~13B
    n = rl.active_params(get_config("mixtral-8x7b"))
    assert 1.0e10 < n < 1.6e10, n
    # mamba2-130m ~ 130-180M (incl. untied embeddings)
    n = rl.active_params(get_config("mamba2-130m"))
    assert 1.0e8 < n < 2.2e8, n


def test_train_launcher_e2e_with_resume(tmp_path):
    from repro.launch import train as train_cli
    d = str(tmp_path / "ck")
    state, losses = train_cli.main([
        "--arch", "qwen2.5-3b", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "16", "--ckpt-dir", d,
        "--ckpt-every", "5", "--log-every", "50"])
    assert len(losses) == 12
    assert np.all(np.isfinite(losses))
    # resume: starts from the saved step, runs the remainder only
    state2, losses2 = train_cli.main([
        "--arch", "qwen2.5-3b", "--reduced", "--steps", "14",
        "--batch", "4", "--seq", "16", "--ckpt-dir", d,
        "--ckpt-every", "50", "--log-every", "50"])
    assert len(losses2) == 2  # resumed at 12


def test_serve_launcher_e2e():
    from repro.launch import serve as serve_cli
    reqs = serve_cli.main(["--arch", "mamba2-130m", "--reduced",
                           "--requests", "3", "--prompt-len", "6",
                           "--max-new", "4"])
    assert all(len(r.out) == 4 for r in reqs)
