"""Deeper invariants: KKT conditions at the CD fixed point (hypothesis),
and elastic checkpoint restore onto a different mesh (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cox, solvers
from repro.data.synthetic import SyntheticSpec, make_correlated_survival


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6), st.floats(0.5, 4.0))
def test_l1_fixed_point_satisfies_kkt(seed, lam1):
    """At the converged l1+l2 CD solution: |grad_l + 2 lam2 b_l| <= lam1
    for zero coords; == -lam1*sign(b_l) for active coords (subgradient
    stationarity). This certifies the solver actually solves the stated
    problem, not merely decreases it."""
    x, t, delta, _ = make_correlated_survival(
        SyntheticSpec(n=250, p=15, k=4, rho=0.6, seed=seed % 13,
                      censor_scale=3.0))
    lam2 = 0.5
    data = cox.prepare(x.astype(np.float64), t, delta)
    res = solvers.fit_cd(data, lam1=lam1, lam2=lam2, n_iters=400)
    beta = res.beta
    g = np.asarray(cox.grad_all(data, data.x @ beta)) \
        + 2.0 * lam2 * np.asarray(beta)
    b = np.asarray(beta)
    tol = 1e-3 * max(lam1, 1.0)  # f32 pipeline: grad residual ~2e-4
    for l in range(len(b)):
        if abs(b[l]) < 1e-10:
            assert abs(g[l]) <= lam1 + tol, (l, g[l], lam1)
        else:
            assert abs(g[l] + lam1 * np.sign(b[l])) <= tol, (l, g[l], b[l])


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt

tmp = os.environ["ELASTIC_TMP"]
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
tree = {"w": jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh_a, P("data", "model"))),
        "b": jax.device_put(jnp.ones((8,)), NamedSharding(mesh_a, P("data")))}
ckpt.save(tmp, 5, tree)

# restore onto the RESIZED mesh (elastic data axis 4 -> 2)
shards = {"w": NamedSharding(mesh_b, P("data", "model")),
          "b": NamedSharding(mesh_b, P("data"))}
restored = ckpt.restore(tmp, tree, step=5, shardings=shards)
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64).reshape(8, 8))
assert restored["w"].sharding.mesh.shape["data"] == 2
print("ELASTIC_OK")
"""


def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["ELASTIC_TMP"] = str(tmp_path / "ck")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in out.stdout, out.stdout + "\n---\n" + out.stderr
