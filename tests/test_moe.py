"""MoE scatter-dispatch correctness against a dense (compute-all-experts)
reference when capacity is not binding, plus dropping semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe


def dense_reference(params, x, k):
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ params["router"], -1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / topv.sum(-1, keepdims=True)
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["w_gate"]))
         * jnp.einsum("td,edf->tef", xt, params["w_up"]))
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # (T, E, D)
    gate = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], topi].set(topv)
    return jnp.einsum("ted,te->td", y_all, gate).reshape(b, s, d)


def test_moe_matches_dense_when_capacity_loose():
    rng = jax.random.PRNGKey(0)
    params = moe.init_moe(rng, 32, 64, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    out, aux = moe.moe_ffn(params, x, 2, capacity_factor=8.0)  # no dropping
    ref = dense_reference(params, x, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.5  # ~1 for balanced routing


def test_moe_drops_overflow_tokens_gracefully():
    rng = jax.random.PRNGKey(2)
    params = moe.init_moe(rng, 16, 32, 2, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 16), jnp.float32)
    out, _ = moe.moe_ffn(params, x, 2, capacity_factor=0.25)  # heavy drop
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))
    # dropped tokens produce strictly smaller output norm than loose capacity
    out_loose, _ = moe.moe_ffn(params, x, 2, capacity_factor=8.0)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(out_loose))
