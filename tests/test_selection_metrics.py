"""Beam search support recovery (Fig. 2 regime, reduced), reg-path, and
survival metrics sanity."""
import numpy as np
import pytest

from repro.core import beam, cox, path
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.survival import metrics


@pytest.fixture(scope="module")
def corr_problem():
    spec = SyntheticSpec(n=400, p=60, k=4, rho=0.9, seed=1)
    x, t, delta, beta_star = make_correlated_survival(spec)
    return cox.prepare(x, t, delta), beta_star, (x, t, delta)


def test_beam_search_recovers_support_high_corr(corr_problem):
    data, beta_star, _ = corr_problem
    k_true = int((beta_star != 0).sum())
    res = beam.beam_search(data, k=k_true, beam_width=4, n_expand=6)
    _, _, f1 = metrics.support_f1(beta_star, res.betas[-1])
    assert f1 >= 0.75, f1
    # loss decreases as support grows
    assert all(np.diff(res.losses) <= 1e-6)


def test_beam_beats_or_matches_omp(corr_problem):
    data, beta_star, _ = corr_problem
    k_true = int((beta_star != 0).sum())
    res_b = beam.beam_search(data, k=k_true, beam_width=4, n_expand=6)
    res_o = beam.omp_greedy(data, k=k_true)
    assert res_b.losses[-1] <= res_o.losses[-1] + 1e-4


def test_l1_path_monotone_support(corr_problem):
    data, _, _ = corr_problem
    pr = path.l1_path(data, n_lambdas=8, lambda_min_ratio=0.05, n_iters=40)
    assert pr.support_sizes[0] <= 1
    assert pr.support_sizes[-1] >= pr.support_sizes[0]
    assert np.all(np.isfinite(pr.losses))
    # stronger penalty -> higher (worse) unpenalized loss
    assert pr.losses[0] >= pr.losses[-1] - 1e-6


def test_lambda_max_kills_all_coefficients(corr_problem):
    data, _, _ = corr_problem
    from repro.core import solvers
    lmax = path.lambda_max(data)
    res = solvers.fit_cd(data, lam1=lmax * 1.01, lam2=0.0, n_iters=20)
    assert np.all(np.abs(np.asarray(res.beta)) < 1e-10)


def test_cindex_perfect_and_random():
    rng = np.random.default_rng(0)
    n = 200
    t = rng.uniform(0, 1, n)
    delta = np.ones(n)
    # risk exactly anti-ordered with time -> perfect concordance
    assert metrics.cindex(t, delta, -t) == 1.0
    assert metrics.cindex(t, delta, t) == 0.0
    r = metrics.cindex(t, delta, rng.standard_normal(n))
    assert 0.4 < r < 0.6


def test_cindex_against_naive():
    rng = np.random.default_rng(1)
    n = 80
    t = np.round(rng.uniform(0, 1, n), 2)  # some ties
    delta = (rng.uniform(size=n) < 0.6).astype(float)
    risk = rng.standard_normal(n)
    num, den = 0.0, 0
    for i in range(n):
        for j in range(n):
            if delta[i] == 1 and t[i] < t[j]:
                den += 1
                if risk[i] > risk[j]:
                    num += 1
                elif np.isclose(risk[i], risk[j]):
                    num += 0.5
    assert np.isclose(metrics.cindex(t, delta, risk), num / den)


def test_ibs_discriminative_model_beats_null(corr_problem):
    data, beta_star, (x, t, delta) = corr_problem
    eta_good = x @ beta_star
    eta_null = np.zeros(len(t))
    ibs_good = metrics.ibs(t, delta, eta_good, t, delta, eta_good)
    ibs_null = metrics.ibs(t, delta, eta_null, t, delta, eta_null)
    assert ibs_good < ibs_null
    assert 0.0 <= ibs_good <= 0.5


def test_support_f1():
    bs = np.zeros(10)
    bs[[1, 3, 5]] = 1.0
    bh = np.zeros(10)
    bh[[1, 3]] = 0.7
    p, r, f1 = metrics.support_f1(bs, bh)
    assert p == 1.0 and np.isclose(r, 2 / 3)
    assert np.isclose(f1, 0.8)
