"""Property tests (hypothesis) for Theorem 3.4 Lipschitz bounds and the
surrogate minimizers / analytic l1-prox solutions of Appendix A.4/A.5."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cox, surrogate
from repro.data.synthetic import make_tied_survival

jax.config.update("jax_enable_x64", True)

finite = st.floats(min_value=-50, max_value=50, allow_nan=False)
pos = st.floats(min_value=1e-3, max_value=50, allow_nan=False)
nonneg = st.floats(min_value=0.0, max_value=50, allow_nan=False)


# ---------------------------------------------------------------------------
# Theorem 3.4: L2/L3 bound the 2nd/3rd partials at *any* beta
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10**6), st.floats(-2.0, 2.0))
def test_lipschitz_bounds_hold_everywhere(seed, scale):
    x, t, delta = make_tied_survival(n=50, p=4, n_times=8, seed=seed % 17)
    data = cox.prepare(x.astype(np.float64), t, delta)
    l2c, l3c = cox.lipschitz_constants(data)
    rng = np.random.default_rng(seed)
    beta = jnp.asarray(rng.standard_normal(4) * scale)
    eta = data.x @ beta
    for l in range(4):
        _, h, c3 = cox.coord_derivs(data, eta, data.x[:, l], order=3)
        assert -1e-9 <= float(h) <= float(l2c[l]) + 1e-9
        assert abs(float(c3)) <= float(l3c[l]) + 1e-9


def test_surrogates_majorize_along_coordinates():
    """f(x + D e_l) <= quadratic / cubic surrogate value, random D sweep."""
    x, t, delta = make_tied_survival(n=80, p=5, n_times=10, seed=3)
    data = cox.prepare(x.astype(np.float64), t, delta)
    l2c, l3c = cox.lipschitz_constants(data)
    rng = np.random.default_rng(0)
    beta = jnp.asarray(rng.standard_normal(5) * 0.4)
    f0 = cox.objective(data, beta)
    eta = data.x @ beta
    for l in range(5):
        g, h, _ = cox.coord_derivs(data, eta, data.x[:, l])
        for d in rng.standard_normal(12) * 2.0:
            f1 = cox.objective(data, beta.at[l].add(d))
            quad = f0 + g * d + 0.5 * l2c[l] * d * d
            cubic = f0 + g * d + 0.5 * h * d * d + l3c[l] / 6 * abs(d) ** 3
            assert float(f1) <= float(quad) + 1e-8
            assert float(f1) <= float(cubic) + 1e-8


# ---------------------------------------------------------------------------
# Analytic minimizers vs dense grid search
# ---------------------------------------------------------------------------

def _grid_argmin(fn, lo=-300.0, hi=300.0, n=600001):
    grid = jnp.linspace(lo, hi, n)
    vals = fn(grid)
    return grid[jnp.argmin(vals)]


@settings(max_examples=40, deadline=None)
@given(finite, pos)
def test_quad_min(a, b):
    step = surrogate.quad_min(jnp.float64(a), jnp.float64(b))
    assert np.isclose(float(step), -a / b, rtol=1e-10)


@settings(max_examples=40, deadline=None)
@given(finite, nonneg, pos)
def test_cubic_min_vs_grid(a, b, c):
    fn = lambda d: a * d + 0.5 * b * d**2 + c / 6 * jnp.abs(d) ** 3
    step = float(surrogate.cubic_min(jnp.float64(a), jnp.float64(b),
                                     jnp.float64(c)))
    ref = float(_grid_argmin(fn))
    assert float(fn(jnp.float64(step))) <= float(fn(jnp.float64(ref))) + 1e-5


@settings(max_examples=60, deadline=None)
@given(finite, pos, finite, nonneg)
def test_quad_l1_prox_vs_grid(a, b, c, lam1):
    fn = lambda d: a * d + 0.5 * b * d**2 + lam1 * jnp.abs(c + d)
    step = float(surrogate.quad_l1_prox(
        jnp.float64(a), jnp.float64(b), jnp.float64(c), jnp.float64(lam1)))
    ref = float(_grid_argmin(fn))
    assert float(fn(jnp.float64(step))) <= float(fn(jnp.float64(ref))) + 1e-5


@settings(max_examples=60, deadline=None)
@given(finite, nonneg, pos, finite, nonneg)
def test_cubic_l1_prox_vs_grid(a, b, c, d, lam1):
    fn = lambda dd: (a * dd + 0.5 * b * dd**2 + c / 6 * jnp.abs(dd) ** 3
                     + lam1 * jnp.abs(d + dd))
    step = float(surrogate.cubic_l1_prox(
        jnp.float64(a), jnp.float64(b), jnp.float64(c), jnp.float64(d),
        jnp.float64(lam1)))
    ref = float(_grid_argmin(fn))
    assert float(fn(jnp.float64(step))) <= float(fn(jnp.float64(ref))) + 1e-5


@settings(max_examples=60, deadline=None)
@given(finite, nonneg, pos, finite, nonneg)
def test_cubic_l1_prox_paper_formula_agrees(a, b, c, d, lam1):
    """Eq. (22) literal formula reaches the same objective value as the
    robust candidate-enumeration solver."""
    fn = lambda dd: (a * dd + 0.5 * b * dd**2 + c / 6 * jnp.abs(dd) ** 3
                     + lam1 * jnp.abs(d + dd))
    s_rob = float(surrogate.cubic_l1_prox(
        jnp.float64(a), jnp.float64(b), jnp.float64(c), jnp.float64(d),
        jnp.float64(lam1)))
    s_pap = float(surrogate.cubic_l1_prox_paper(
        jnp.float64(a), jnp.float64(b), jnp.float64(c), jnp.float64(d),
        jnp.float64(lam1)))
    assert np.isclose(float(fn(jnp.float64(s_pap))),
                      float(fn(jnp.float64(s_rob))), rtol=1e-6, atol=1e-6)
