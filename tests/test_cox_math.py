"""Theorem 3.1 / Lemma 3.2 / Corollary 3.3 validation against autodiff.

The loss is written independently (naive O(n^2) risk-set form) and the
paper's O(n) formulas are checked against jax.grad / nested grads of it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cox
from repro.data.synthetic import make_tied_survival

jax.config.update("jax_enable_x64", True)


def naive_loss(x, t, delta, beta):
    """O(n^2) direct implementation of Eq. (4) with Breslow risk sets."""
    eta = x @ beta
    n = x.shape[0]
    total = 0.0
    for i in range(n):
        mask = t >= t[i]
        total = total + delta[i] * (
            jnp.log(jnp.sum(mask * jnp.exp(eta))) - eta[i]
        )
    return total


@pytest.fixture(scope="module")
def small():
    x, t, delta = make_tied_survival(n=60, p=5, n_times=12, seed=1)
    x = x.astype(np.float64)
    data = cox.prepare(x, t, delta)
    rng = np.random.default_rng(3)
    beta = rng.standard_normal(5) * 0.3
    return x, t, delta, data, jnp.asarray(beta)


def test_loss_matches_naive(small):
    x, t, delta, data, beta = small
    ours = cox.objective(data, beta)
    ref = naive_loss(jnp.asarray(x), jnp.asarray(t), jnp.asarray(delta), beta)
    np.testing.assert_allclose(ours, ref, rtol=1e-10)


def test_grad_all_matches_autodiff(small):
    x, t, delta, data, beta = small
    g_ref = jax.grad(
        lambda b: naive_loss(jnp.asarray(x), jnp.asarray(t),
                             jnp.asarray(delta), b))(beta)
    g = cox.grad_all(data, data.x @ beta)
    np.testing.assert_allclose(g, g_ref, rtol=1e-8, atol=1e-10)


def test_coord_derivs_match_autodiff(small):
    x, t, delta, data, beta = small
    xj, tj, dj = jnp.asarray(x), jnp.asarray(t), jnp.asarray(delta)
    f = lambda b: naive_loss(xj, tj, dj, b)
    g_ref = jax.grad(f)(beta)
    h_ref = jnp.diagonal(jax.hessian(f)(beta))
    for l in range(data.p):
        # third derivative along coordinate l via nested scalar grads
        fl = lambda s: f(beta.at[l].set(s))
        d3 = jax.grad(jax.grad(jax.grad(fl)))(beta[l])
        g, h, c3 = cox.coord_derivs(data, data.x @ beta, data.x[:, l], order=3)
        np.testing.assert_allclose(g, g_ref[l], rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(h, h_ref[l], rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(c3, d3, rtol=1e-6, atol=1e-8)


def test_grad_hess_all_matches_coord(small):
    _, _, _, data, beta = small
    eta = data.x @ beta
    g_all, h_all = cox.grad_hess_all(data, eta)
    for l in range(data.p):
        g, h, _ = cox.coord_derivs(data, eta, data.x[:, l])
        np.testing.assert_allclose(g_all[l], g, rtol=1e-9)
        np.testing.assert_allclose(h_all[l], h, rtol=1e-9)


def test_exact_hessian_matches_autodiff(small):
    x, t, delta, data, beta = small
    xj, tj, dj = jnp.asarray(x), jnp.asarray(t), jnp.asarray(delta)
    h_ref = jax.hessian(lambda b: naive_loss(xj, tj, dj, b))(beta)
    h = cox.exact_hessian(data, data.x @ beta)
    np.testing.assert_allclose(h, h_ref, rtol=1e-7, atol=1e-9)


def test_eta_gradient_matches_autodiff(small):
    _, _, _, data, beta = small
    eta = data.x @ beta
    g_ref = jax.grad(lambda e: cox.loss_from_eta(data, e))(eta)
    np.testing.assert_allclose(cox.eta_gradient(data, eta), g_ref,
                               rtol=1e-8, atol=1e-10)


def test_eta_hessian_diag_matches_autodiff(small):
    _, _, _, data, beta = small
    eta = data.x @ beta
    h_full = jax.hessian(lambda e: cox.loss_from_eta(data, e))(eta)
    np.testing.assert_allclose(
        cox.eta_hessian_diag(data, eta), jnp.diagonal(h_full),
        rtol=1e-7, atol=1e-10)
    # majorant dominates the diagonal
    assert np.all(np.asarray(cox.eta_hessian_upper(data, eta))
                  >= np.asarray(jnp.diagonal(h_full)) - 1e-12)


def test_moment_recursion_lemma_3_2(small):
    """dC_r/dbeta_l == C_{r+1} - r C_2 C_{r-1}, checked per event row."""
    _, _, _, data, beta = small
    l = 2
    xl = data.x[:, l]

    def cr_of_beta(b, r):
        return cox.central_moment(data, data.x @ b, xl, r)

    for r in (2, 3, 4):
        jac = jax.jacobian(lambda b: cr_of_beta(b, r))(beta)[:, l]
        rhs = (cr_of_beta(beta, r + 1)
               - r * cr_of_beta(beta, 2) * cr_of_beta(beta, r - 1))
        np.testing.assert_allclose(jac, rhs, rtol=1e-6, atol=1e-9)


def test_third_derivative_not_fourth_moment(small):
    """Sanity for the paper's negative result: for r>=3 the pattern breaks;
    C_2' == C_3 but C_3' != C_4 in general."""
    _, _, _, data, beta = small
    l = 1
    xl = data.x[:, l]
    jac3 = jax.jacobian(
        lambda b: cox.central_moment(data, data.x @ b, xl, 3))(beta)[:, l]
    c4 = cox.central_moment(data, data.x @ beta, xl, 4)
    assert not np.allclose(np.asarray(jac3), np.asarray(c4), rtol=1e-3)
