"""Per-architecture smoke tests: reduced same-family config, one forward /
train-loss evaluation and one prefill->decode step on CPU; asserts output
shapes and absence of NaNs. (Full configs are exercised only via the
dry-run with ShapeDtypeStructs.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced_config
from repro.models import build_model

ARCHS = sorted(REGISTRY)


def make_batch(cfg, rng, bsz=2, seq=24, train=True):
    batch = {}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            rng, (bsz, seq, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(rng, (bsz, seq), 0,
                                             cfg.vocab_size)
    elif cfg.frontend in ("audio", "vision"):
        batch["embeds"] = jax.random.normal(rng, (bsz, seq, cfg.d_model),
                                            jnp.float32)
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(seq)[None, :], (bsz, seq))
            batch["positions"] = jnp.stack([pos, pos, pos])
    else:
        batch["tokens"] = jax.random.randint(rng, (bsz, seq), 0,
                                             cfg.vocab_size)
    if train:
        batch["labels"] = jax.random.randint(rng, (bsz, seq), 0,
                                             cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_finite(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(model.loss_lm)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grads_finite(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng)
    batch = make_batch(cfg, rng)
    grads = jax.jit(jax.grad(lambda p: model.loss_lm(p, batch)[0]))(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # at least one grad is nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode_step(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init_params(rng)
    bsz, seq = 2, 24
    batch = make_batch(cfg, rng, bsz=bsz, seq=seq, train=False)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (bsz, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits[:, : cfg.vocab_size])))
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None] \
        .astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (bsz, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits2[:, : cfg.vocab_size])))
    assert int(cache2.length[0]) == seq + 1


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-130m",
                                  "deepseek-67b", "qwen1.5-4b"])
def test_decode_matches_full_forward(arch):
    """Cache correctness: decoding token S after prefilling S tokens must
    match the full forward over S+1 tokens (full-attention / SSM archs)."""
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init_params(rng)
    bsz, seq = 2, 17
    tokens = jax.random.randint(rng, (bsz, seq + 1), 0, cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": tokens[:, :seq]})
    dec_logits, _ = jax.jit(model.decode_step)(
        params, cache, tokens[:, seq:seq + 1].astype(jnp.int32))
    hidden, _, _ = model.hidden_states(params, {"tokens": tokens},
                                       remat=False)
    full_logits = model._logits(params, hidden[:, seq])
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, : cfg.vocab_size]),
        np.asarray(full_logits[:, : cfg.vocab_size]), rtol=2e-3, atol=2e-3)


def test_swa_rolling_cache_matches_windowed_forward():
    """After prefill of S > window, one decode step against the rolling
    cache must equal the full forward (windowed attention) on S+1 tokens.

    Uses a dense+SWA config: MoE archs drop tokens when an expert exceeds
    capacity, so prefill(S) vs forward(S+1) are not bit-comparable there
    (that nondeterminism is inherent to capacity routing, not the cache).
    """
    cfg = reduced_config(REGISTRY["mixtral-8x7b"]).scaled(
        n_experts=0, n_experts_per_tok=0, family="dense")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(4)
    params = model.init_params(rng)
    bsz, seq = 2, 37  # > window 16, not a multiple of it
    tokens = jax.random.randint(rng, (bsz, seq + 1), 0, cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": tokens[:, :seq]})
    assert cache.k.shape[2] == cfg.sliding_window
    dec_logits, _ = jax.jit(model.decode_step)(
        params, cache, tokens[:, seq:seq + 1].astype(jnp.int32))
    hidden, _, _ = model.hidden_states(params, {"tokens": tokens},
                                       remat=False)
    full_logits = model._logits(params, hidden[:, seq])
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, : cfg.vocab_size]),
        np.asarray(full_logits[:, : cfg.vocab_size]), rtol=2e-3, atol=2e-3)
