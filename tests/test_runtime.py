"""Runtime substrate: optimizer, trainer loop (loss goes down), checkpoint
save/restore roundtrip + async + resume, straggler monitor, gradient
compression, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, TrainConfig, reduced_config
from repro.data.pipeline import SurvivalTextStream, TokenTaskStream
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train import compression, fault_tolerance as ft
from repro.train.trainer import TrainState, init_train_state, make_train_step


def _tiny_setup(arch="qwen2.5-3b", objective="lm"):
    cfg = reduced_config(REGISTRY[arch]).scaled(vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if objective == "cox":
        from repro.survival.head import init_cox_head
        params["cox_head"] = init_cox_head(jax.random.PRNGKey(1),
                                           cfg.d_model)
    from repro.train.optimizer import init_opt_state
    state = TrainState(params=params, opt=init_opt_state(params))
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=200)
    step_fn = jax.jit(make_train_step(model, tcfg, objective))
    return cfg, model, state, step_fn


def test_train_loop_loss_decreases():
    cfg, model, state, step_fn = _tiny_setup()
    stream = TokenTaskStream(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    for i in range(40):
        state, m = step_fn(state, stream.batch_for_step(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_cox_objective_trains():
    cfg, model, state, step_fn = _tiny_setup(objective="cox")
    stream = SurvivalTextStream(cfg.vocab_size, 32, 16, seed=0)
    losses = []
    for i in range(25):
        state, m = step_fn(state, stream.batch_for_step(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_microbatch_accumulation_matches_full_batch():
    cfg, model, state, _ = _tiny_setup()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=5, microbatch=4)
    step_acc = jax.jit(make_train_step(model, tcfg))
    step_full = jax.jit(make_train_step(
        model, TrainConfig(learning_rate=1e-3, warmup_steps=5)))
    batch = TokenTaskStream(cfg.vocab_size, 32, 8, seed=1).batch_for_step(0)
    s1, m1 = step_acc(state, batch)
    s2, m2 = step_full(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, model, state, step_fn = _tiny_setup()
    stream = TokenTaskStream(cfg.vocab_size, 32, 8, seed=0)
    for i in range(3):
        state, _ = step_fn(state, stream.batch_for_step(i))
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 3, state)
    assert ckpt.latest_step(d) == 3
    restored, start = ft.resume_or_init(
        d, lambda: init_train_state(model, jax.random.PRNGKey(0)))
    assert start == 3
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # continue training from the restored state — bitwise same trajectory
    s_direct, m_direct = step_fn(state, stream.batch_for_step(3))
    s_res, m_res = step_fn(restored, stream.batch_for_step(3))
    np.testing.assert_allclose(float(m_direct["loss"]), float(m_res["loss"]),
                               rtol=1e-6)


def test_async_checkpointer(tmp_path):
    cfg, model, state, _ = _tiny_setup()
    d = str(tmp_path / "ckpt")
    ac = ckpt.AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ac.save(s, state)
    ac.wait()
    assert ckpt.latest_step(d) == 3
    steps = sorted(os.listdir(d))
    assert len([x for x in steps if x.startswith("step_")]) == 2  # keep=2


def test_straggler_monitor():
    mon = ft.StragglerMonitor(factor=3.0)
    flags = [mon.record(1.0) for _ in range(10)]
    assert not any(flags)
    assert mon.record(10.0) is True
    assert mon.n_stragglers == 1
    # EWMA not poisoned by the straggler
    assert mon.ewma < 1.5


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal(1000), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((32, 7)), jnp.float32)}
    res = jax.tree.map(jnp.zeros_like, g)
    # single-shot quantization error is bounded
    gh, res = compression.compress_decompress(g, res)
    err = float(jnp.abs(gh["a"] - g["a"]).max())
    assert err < 0.05
    # error feedback: accumulated mean over steps converges to true mean
    total_true = jax.tree.map(jnp.zeros_like, g)
    total_hat = jax.tree.map(jnp.zeros_like, g)
    res = jax.tree.map(jnp.zeros_like, g)
    for i in range(50):
        gi = jax.tree.map(
            lambda x: x * (1.0 + 0.01 * i), g)
        gh, res = compression.compress_decompress(gi, res)
        total_true = jax.tree.map(jnp.add, total_true, gi)
        total_hat = jax.tree.map(jnp.add, total_hat, gh)
    rel = (float(jnp.abs(total_hat["a"] - total_true["a"]).max())
           / float(jnp.abs(total_true["a"]).max()))
    assert rel < 0.01


def test_pipeline_determinism():
    s1 = TokenTaskStream(128, 16, 4, seed=42)
    s2 = TokenTaskStream(128, 16, 4, seed=42)
    b1, b2 = s1.batch_for_step(7), s2.batch_for_step(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch_for_step(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
