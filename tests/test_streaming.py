"""Streaming Cox (core/streaming.py + solvers.fit_stream) and the
shard-aware scoring engine."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cox, solvers, streaming
from repro.obs import TelemetryCallback
from repro.serving.artifacts import fit_survival_model
from repro.serving.engine import ScoringEngine


def _make_data(n, p, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    t = rng.exponential(size=n).astype(np.float32)  # continuous: tie-free
    delta = (rng.uniform(size=n) < 0.7).astype(np.float32)
    return cox.prepare(x, t, delta)


# ---------------------------------------------------------------------------
# chunked suffix-sum carry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("ndim", [1, 2])
def test_chunked_revcumsum_random_boundaries(seed, ndim):
    rng = np.random.default_rng(seed)
    n = 777
    shape = (n,) if ndim == 1 else (n, 5)
    v = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    k = rng.integers(1, 7)
    bounds = sorted(rng.choice(np.arange(1, n), size=k, replace=False))
    edges = [0] + list(bounds) + [n]
    segs = [v[a:b] for a, b in zip(edges[:-1], edges[1:])]
    outs = streaming.chunked_revcumsum(segs, use_kernel=False)
    mono = jax.lax.cumsum(v, axis=0, reverse=True)
    np.testing.assert_allclose(np.concatenate([np.asarray(o) for o in outs]),
                               np.asarray(mono), rtol=1e-5, atol=1e-5)


def test_chunked_revcumsum_kernel_path():
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.standard_normal(300).astype(np.float32))
    segs = [v[:128], v[128:200], v[200:]]
    outs = streaming.chunked_revcumsum(segs, use_kernel=True)
    mono = jax.lax.cumsum(v, axis=0, reverse=True)
    np.testing.assert_allclose(np.concatenate([np.asarray(o) for o in outs]),
                               np.asarray(mono), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# streaming statistics match the monolithic reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_rows", [97, 250, 1000])
def test_streaming_grad_hess_matches_monolithic(chunk_rows):
    data = _make_data(1000, 7, seed=4)
    rng = np.random.default_rng(5)
    beta = jnp.asarray(rng.standard_normal(7).astype(np.float32) * 0.3)
    src = streaming.as_chunks(data, chunk_rows)
    g, h, loss = streaming.streaming_grad_hess(src, beta)
    eta = data.x @ beta
    g_r, h_r = cox.grad_hess_all(data, eta)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(loss),
                               float(cox.loss_from_eta(data, eta)),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(float(streaming.streaming_loss(src, beta)),
                               float(cox.loss_from_eta(data, eta)),
                               rtol=1e-5, atol=1e-3)


def test_streaming_accepts_plain_chunk_list():
    data = _make_data(300, 4, seed=6)
    src = [streaming.Chunk(x=data.x[:100], delta=data.delta[:100]),
           streaming.Chunk(x=data.x[100:], delta=data.delta[100:])]
    beta = jnp.zeros(4, jnp.float32)
    g, _, _ = streaming.streaming_grad_hess(src, beta)
    g_r = cox.grad_all(data, data.x @ beta)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_r),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fit_stream
# ---------------------------------------------------------------------------

def test_fit_stream_single_chunk_matches_fit_cd():
    data = _make_data(600, 6, seed=7)
    res_cd = solvers.fit_cd(data, lam1=0.02, lam2=0.01, n_iters=200)
    src = streaming.as_chunks(data, data.n)   # one full-size chunk
    res_st = solvers.fit_stream(src, lam1=0.02, lam2=0.01,
                                n_epochs=500, tol=1e-10)
    f_cd = float(res_cd.objective[-1])
    f_st = float(res_st.objective[-1])
    assert abs(f_st - f_cd) <= 1e-4 * abs(f_cd), (f_st, f_cd)


def test_fit_stream_multichunk_global_matches_fit_cd():
    data = _make_data(600, 6, seed=8)
    res_cd = solvers.fit_cd(data, lam1=0.02, lam2=0.01, n_iters=200)
    src = streaming.as_chunks(data, 128)
    res_st = solvers.fit_stream(src, lam1=0.02, lam2=0.01,
                                n_epochs=500, tol=1e-10)
    f_cd = float(res_cd.objective[-1])
    f_st = float(res_st.objective[-1])
    assert abs(f_st - f_cd) <= 1e-4 * abs(f_cd), (f_st, f_cd)


def test_fit_stream_chunk_mode_descends_zero_violations():
    data = _make_data(512, 5, seed=9)
    src = streaming.as_chunks(data, 128)
    tel = TelemetryCallback(solver="fit_stream_test")
    res = solvers.fit_stream(src, lam2=0.05, n_epochs=25, mode="chunk",
                             telemetry=tel)
    obj = np.asarray(res.objective)
    assert np.all(np.diff(obj) <= 1e-6), obj
    assert tel.violations == 0
    assert tel.iterations >= 1


def test_fit_stream_rejects_unknown_mode():
    data = _make_data(64, 3, seed=10)
    with pytest.raises(ValueError):
        solvers.fit_stream(streaming.as_chunks(data, 32), mode="nope")


# ---------------------------------------------------------------------------
# shard-aware scoring engine
# ---------------------------------------------------------------------------

def test_engine_shard_resolution_and_bucketing():
    data_rng = np.random.default_rng(11)
    x = data_rng.standard_normal((100, 4)).astype(np.float32)
    t = data_rng.exponential(size=100).astype(np.float32)
    d = (data_rng.uniform(size=100) < 0.6).astype(np.float32)
    beta = data_rng.standard_normal(4).astype(np.float32) * 0.2
    model = fit_survival_model(x, t, d, beta)

    e = ScoringEngine(model)                       # legacy default
    assert e.shard == 1 and e._mesh is None
    assert e._pad(np.zeros((37, 4), np.float32))[2] == 64

    # explicit shard counts clamp to the local device count (1 here)
    e2 = ScoringEngine(model, shard=4)
    assert e2.shard == jax.local_device_count()

    os.environ["REPRO_DATA_SHARDS"] = "1"
    try:
        assert ScoringEngine(model, shard="auto").shard == 1
    finally:
        del os.environ["REPRO_DATA_SHARDS"]


def test_engine_per_shard_bucketing_math():
    # bucket = shards * next_pow2(ceil(b / shards)); verified without
    # devices by faking the resolved shard count
    rng = np.random.default_rng(12)
    x = rng.standard_normal((50, 3)).astype(np.float32)
    t = rng.exponential(size=50).astype(np.float32)
    d = np.ones(50, np.float32)
    model = fit_survival_model(x, t, d, np.zeros(3, np.float32))
    e = ScoringEngine(model)
    e.shard = 2
    for b, want in [(1, 2), (2, 2), (3, 4), (37, 64), (64, 64), (65, 128)]:
        assert e._pad(np.zeros((b, 3), np.float32))[2] == want, b


SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
from repro.serving.artifacts import fit_survival_model
from repro.serving.engine import ScoringEngine

rng = np.random.default_rng(0)
n, p = 300, 6
x = rng.standard_normal((n, p)).astype(np.float32)
t = rng.exponential(size=n).astype(np.float32)
d = (rng.uniform(size=n) < 0.7).astype(np.float32)
beta = rng.standard_normal(p).astype(np.float32) * 0.3
strata = rng.integers(0, 3, n)
model = fit_survival_model(x, t, d, beta, strata=strata)

e1 = ScoringEngine(model, shard=None)
e2 = ScoringEngine(model, shard=2)
assert e2.shard == 2, e2.shard
xq = rng.standard_normal((41, p)).astype(np.float32)
sq = rng.integers(0, 3, 41)
np.testing.assert_array_equal(e1.risk_scores(xq), e2.risk_scores(xq))
np.testing.assert_array_equal(e1.survival_curves(xq, sq),
                              e2.survival_curves(xq, sq))
np.testing.assert_array_equal(e1.median_survival(xq, sq),
                              e2.median_survival(xq, sq))
r1, m1, c1 = e1.score(xq, sq, with_curves=True)
r2, m2, c2 = e2.score(xq, sq, with_curves=True)
np.testing.assert_array_equal(r1, r2)
np.testing.assert_array_equal(c1, c2)
print("ALL_OK")
"""


def test_sharded_scoring_parity_subprocess():
    """2-shard host-mesh scoring equals unsharded, bit for bit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ALL_OK" in out.stdout, out.stdout + "\n---\n" + out.stderr
