"""Extensions beyond the paper's main algorithm: SCAD/MCP penalties
(§3.5's list), stratified CPH, Efron ties, k-fold CV driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cox, penalties, solvers, stratified
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.survival import cv, metrics

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# SCAD / MCP proxes vs grid search
# ---------------------------------------------------------------------------

def _grid_min(fn, lo=-60.0, hi=60.0, n=240001):
    g = jnp.linspace(lo, hi, n)
    return float(g[jnp.argmin(fn(g))])


@settings(max_examples=30, deadline=None)
@given(st.floats(-10, 10), st.floats(1.0, 20.0), st.floats(-5, 5),
       st.floats(0.05, 2.0))
def test_mcp_prox_vs_grid(a, b, c, lam):
    gamma = 3.0

    def obj(d):
        return (a * d + 0.5 * b * d**2
                + penalties.mcp_value(jnp.atleast_1d(c + d), lam, gamma))

    step = float(penalties.mcp_prox(jnp.float64(a), jnp.float64(b),
                                    jnp.float64(c), jnp.float64(lam), gamma))
    ref = _grid_min(lambda d: jax.vmap(obj)(d))
    assert float(obj(step)) <= float(obj(ref)) + 1e-4


@settings(max_examples=30, deadline=None)
@given(st.floats(-10, 10), st.floats(1.0, 20.0), st.floats(-5, 5),
       st.floats(0.05, 2.0))
def test_scad_prox_vs_grid(a, b, c, lam):
    gamma = 3.7

    def obj(d):
        return (a * d + 0.5 * b * d**2
                + penalties.scad_value(jnp.atleast_1d(c + d), lam, gamma))

    step = float(penalties.scad_prox(jnp.float64(a), jnp.float64(b),
                                     jnp.float64(c), jnp.float64(lam), gamma))
    ref = _grid_min(lambda d: jax.vmap(obj)(d))
    assert float(obj(step)) <= float(obj(ref)) + 1e-4


def test_scad_mcp_cd_recover_support():
    """Nonconvex-penalty CD on correlated data: with lam scaled to the
    problem (0.4 * lambda_max), SCAD/MCP recover a near-true sparse support
    with monotone objective decrease."""
    from repro.core import path

    x, t, delta, beta_star = make_correlated_survival(
        SyntheticSpec(n=500, p=60, k=5, rho=0.6, seed=4, censor_scale=3.0))
    data = cox.prepare(x.astype(np.float64), t, delta)
    lam = 0.4 * path.lambda_max(data)
    for pen in ("scad", "mcp"):
        res = solvers.fit_cd_penalized(data, penalty=pen, lam1=lam,
                                       n_iters=200)
        obj = np.asarray(res.objective)
        assert np.all(np.isfinite(obj))
        assert np.all(np.diff(obj) <= 1e-6 * abs(obj[0])), pen
        b = np.asarray(res.beta)
        nnz = int((np.abs(b) > 1e-8).sum())
        _, _, f1 = metrics.support_f1(beta_star, b)
        assert nnz <= 12, (pen, nnz)
        assert f1 >= 0.8, (pen, f1)


# ---------------------------------------------------------------------------
# Stratified CPH
# ---------------------------------------------------------------------------

def test_stratified_loss_equals_sum_of_per_stratum_losses():
    rng = np.random.default_rng(0)
    n, p = 120, 5
    x = rng.standard_normal((n, p))
    t = rng.uniform(1, 2, n)
    delta = (rng.uniform(size=n) < 0.7).astype(float)
    strata = rng.integers(0, 3, n)
    beta = jnp.asarray(rng.standard_normal(p) * 0.4)

    total = stratified.stratified_loss(x, t, delta, strata, beta)
    expect = 0.0
    for s in range(3):
        m = strata == s
        data_s = cox.prepare(x[m], t[m], delta[m])
        expect += float(cox.loss_from_eta(data_s, data_s.x @ beta))
    np.testing.assert_allclose(float(total), expect, rtol=1e-8)


def test_stratified_single_stratum_matches_plain():
    rng = np.random.default_rng(1)
    n, p = 80, 4
    x = rng.standard_normal((n, p))
    t = np.round(rng.uniform(1, 2, n), 2)  # ties too
    delta = (rng.uniform(size=n) < 0.7).astype(float)
    beta = jnp.asarray(rng.standard_normal(p) * 0.3)
    data = cox.prepare(x, t, delta)
    plain = float(cox.loss_from_eta(data, data.x @ beta))
    strat = float(stratified.stratified_loss(
        x, t, delta, np.zeros(n, np.int32), beta))
    np.testing.assert_allclose(strat, plain, rtol=1e-8)


# ---------------------------------------------------------------------------
# Efron ties
# ---------------------------------------------------------------------------

def test_efron_equals_breslow_without_ties():
    rng = np.random.default_rng(2)
    n = 100
    t = rng.uniform(1, 2, n)  # continuous: no ties
    delta = (rng.uniform(size=n) < 0.6).astype(float)
    eta = jnp.asarray(rng.standard_normal(n) * 0.5)
    data = cox.prepare(np.zeros((n, 1)), t, delta)
    breslow = float(cox.loss_from_eta(data, eta[jnp.argsort(jnp.asarray(t))]))
    efron = float(stratified.efron_loss(jnp.asarray(t),
                                        jnp.asarray(delta), eta))
    np.testing.assert_allclose(efron, breslow, rtol=1e-7)


def test_efron_less_than_breslow_with_ties():
    """Efron's correction shrinks the risk set within a tie group, so the
    per-event log-denominator (and the loss) is <= Breslow's."""
    rng = np.random.default_rng(3)
    n = 120
    t = np.ceil(rng.uniform(0, 1, n) * 8) / 8  # heavy ties
    delta = np.ones(n)
    eta = jnp.asarray(rng.standard_normal(n) * 0.5)
    data = cox.prepare(np.zeros((n, 1)), t, delta)
    order = jnp.argsort(jnp.asarray(t), stable=True)
    breslow = float(cox.loss_from_eta(data, eta[order]))
    efron = float(stratified.efron_loss(jnp.asarray(t),
                                        jnp.asarray(delta), eta))
    assert efron < breslow


# ---------------------------------------------------------------------------
# CV driver
# ---------------------------------------------------------------------------

def test_cross_validation_protocol():
    x, t, delta, beta_star = make_correlated_survival(
        SyntheticSpec(n=300, p=30, k=4, rho=0.5, seed=5, censor_scale=3.0))

    def fit(data):
        return solvers.fit_cd(data, lam2=1.0, n_iters=40).beta

    out = cv.cross_validate(x, t, delta, fit, k=5)
    assert 0.6 < out["cindex_mean"] <= 1.0
    assert out["ibs_mean"] < 0.25
    assert out["cindex_std"] < 0.2
