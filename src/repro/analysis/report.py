"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dryrun_results JSONs.

    PYTHONPATH=src python -m repro.analysis.report [--dir ...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    return f"{x:.2e}"


def load(d):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | status | compile s | args GB/dev | temp GB/dev"
            " | coll ops |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "ok":
            ma = r.get("memory_analysis", {})
            args = ma.get("argument_size_in_bytes", 0) / 2**30
            temp = ma.get("temp_size_in_bytes", 0) / 2**30
            nops = r.get("collectives", {}).get("n_ops", 0)
            rows.append(f"| {r['arch']} | {r['shape']} | ok "
                        f"| {r.get('compile_s','-')} | {args:.2f} "
                        f"| {temp:.2f} | {int(nops)} |")
        else:
            reason = r.get("reason", "error")
            rows.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                        f"{reason} | - | - | - | - |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | useful/compiled flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != "pod16x16" or r["status"] != "ok":
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        # fraction of roofline: ideal time (compute term at 100% useful
        # flops) over the dominating term
        ideal = ro["model_flops"] / 197e12
        frac = ideal / dom if dom > 0 else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} "
            f"| {fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} "
            f"| {ro['bottleneck']} | {ro['useful_flops_ratio']:.2f} "
            f"| {frac:.3f} |")
    return "\n".join(rows)


def serving_kernel_table():
    """Roofline of the serving scoring kernels at canonical QPS shapes."""
    from . import roofline as rl
    shapes = [
        ("survival_curves", {"batch": 64, "grid": 128}),
        ("survival_curves", {"batch": 1024, "grid": 128}),
        ("risk_dense", {"batch": 64, "p": 10000}),
        ("risk_sparse", {"batch": 64, "k": 10}),
        ("risk_sparse", {"batch": 1024, "k": 10}),
    ]
    rows = ["| kernel | shape | flops | bytes | flops/byte | compute s | "
            "memory s | bottleneck |",
            "|---|---|---|---|---|---|---|---|"]
    for name, shape in shapes:
        k = rl.kernel_roofline(name, **shape)
        sh = ",".join(f"{a}={v}" for a, v in shape.items())
        rows.append(
            f"| {name} | {sh} | {k.flops:.2e} | {k.bytes_accessed:.2e} "
            f"| {k.intensity:.2f} | {fmt_s(k.compute_s)} "
            f"| {fmt_s(k.memory_s)} | {k.bottleneck} |")
    return "\n".join(rows)


def latency_breakdown_table(trace_path):
    """Per-stage latency breakdown from a span JSONL file (obs.trace).

    One row per span name: call count, total/mean/p50/p99 milliseconds,
    and share of the summed root-span time — the table that attributes
    serving p99 to queueing vs batch formation vs jit dispatch.
    """
    import numpy as np

    from ..obs import events as obs_events

    spans = [r for r in obs_events.read_jsonl(trace_path)
             if r.get("kind") == "span" and "dur_s" in r]
    rows = ["| stage | count | total ms | mean ms | p50 ms | p99 ms | "
            "% of root |",
            "|---|---|---|---|---|---|---|"]
    if not spans:
        rows.append(f"| (no spans in {os.path.basename(str(trace_path))} — "
                    "set $REPRO_TRACE_FILE and re-run) | | | | | | |")
        return "\n".join(rows)
    root_total = sum(s["dur_s"] for s in spans if s.get("parent_id") is None)
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s["dur_s"] * 1e3)
    for name in sorted(by_name,
                       key=lambda n: -float(np.sum(by_name[n]))):
        d = np.asarray(by_name[name])
        pct = (d.sum() / (root_total * 1e3) * 100.0) if root_total > 0 \
            else 0.0
        rows.append(f"| {name} | {len(d)} | {d.sum():.2f} "
                    f"| {d.mean():.3f} | {np.percentile(d, 50):.3f} "
                    f"| {np.percentile(d, 99):.3f} | {pct:.1f} |")
    return "\n".join(rows)


def tuned_blocks_table(cache_path=None):
    """Autotune winners vs the static default blocks, per backend/bucket.

    Reads the tuned_blocks.json cache written by kernels/autotune.py (plus
    anything already registered in-process via roofline.register_tuned).
    """
    from . import roofline as rl
    if cache_path:
        rl.load_tuned(cache_path)
    rows = ["| kernel | backend | bucket | tuned blocks | tuned us | "
            "default blocks | default us | speedup |",
            "|---|---|---|---|---|---|---|---|"]
    if not rl.TUNED_KERNELS:
        rows.append("| (no autotune winners recorded — run "
                    "`benchmarks/run.py --autotune`) | | | | | | | |")
        return "\n".join(rows)

    def blk(cfg):
        return ",".join(f"{k}={v}" for k, v in sorted((cfg or {}).items()))

    for key in sorted(rl.TUNED_KERNELS):
        e = rl.TUNED_KERNELS[key]
        backend, _, rest = key.partition("/")
        _, _, bucket = rest.partition("/")
        us, dus = e.get("us"), e.get("default_us")
        rows.append("| {} | {} | {} | {} | {} | {} | {} | {} |".format(
            e.get("kernel", key), backend, bucket, blk(e.get("config")),
            f"{us:.1f}" if us else "-", blk(e.get("default_config")),
            f"{dus:.1f}" if dus else "-",
            f"{dus / us:.2f}x" if us and dus else "-"))
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "dryrun_results"))
    ap.add_argument("--tune-cache", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "tuned_blocks.json"))
    ap.add_argument("--trace", default=os.environ.get("REPRO_TRACE_FILE"),
                    help="span JSONL (obs.trace) to summarize into the "
                         "latency-breakdown table")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run (single pod 16x16)\n")
    print(dryrun_table(recs, "pod16x16"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(recs, "pod2x16x16"))
    print("\n## Roofline (single pod, per step)\n")
    print(roofline_table(recs))
    print("\n## Serving kernel roofline (scoring hot path, per call)\n")
    print(serving_kernel_table())
    print("\n## Tuned kernel blocks (autotune winners vs defaults)\n")
    print(tuned_blocks_table(args.tune_cache))
    if args.trace and os.path.exists(args.trace):
        print("\n## Per-stage latency breakdown (telemetry spans)\n")
        print(latency_breakdown_table(args.trace))


if __name__ == "__main__":
    main()
