"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dryrun_results JSONs.

    PYTHONPATH=src python -m repro.analysis.report [--dir ...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    return f"{x:.2e}"


def load(d):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | status | compile s | args GB/dev | temp GB/dev"
            " | coll ops |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "ok":
            ma = r.get("memory_analysis", {})
            args = ma.get("argument_size_in_bytes", 0) / 2**30
            temp = ma.get("temp_size_in_bytes", 0) / 2**30
            nops = r.get("collectives", {}).get("n_ops", 0)
            rows.append(f"| {r['arch']} | {r['shape']} | ok "
                        f"| {r.get('compile_s','-')} | {args:.2f} "
                        f"| {temp:.2f} | {int(nops)} |")
        else:
            reason = r.get("reason", "error")
            rows.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                        f"{reason} | - | - | - | - |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | useful/compiled flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != "pod16x16" or r["status"] != "ok":
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        # fraction of roofline: ideal time (compute term at 100% useful
        # flops) over the dominating term
        ideal = ro["model_flops"] / 197e12
        frac = ideal / dom if dom > 0 else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} "
            f"| {fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} "
            f"| {ro['bottleneck']} | {ro['useful_flops_ratio']:.2f} "
            f"| {frac:.3f} |")
    return "\n".join(rows)


def serving_kernel_table():
    """Roofline of the serving scoring kernels at canonical QPS shapes."""
    from . import roofline as rl
    shapes = [
        ("survival_curves", {"batch": 64, "grid": 128}),
        ("survival_curves", {"batch": 1024, "grid": 128}),
        ("risk_dense", {"batch": 64, "p": 10000}),
        ("risk_sparse", {"batch": 64, "k": 10}),
        ("risk_sparse", {"batch": 1024, "k": 10}),
    ]
    rows = ["| kernel | shape | flops | bytes | flops/byte | compute s | "
            "memory s | bottleneck |",
            "|---|---|---|---|---|---|---|---|"]
    for name, shape in shapes:
        k = rl.kernel_roofline(name, **shape)
        sh = ",".join(f"{a}={v}" for a, v in shape.items())
        rows.append(
            f"| {name} | {sh} | {k.flops:.2e} | {k.bytes_accessed:.2e} "
            f"| {k.intensity:.2f} | {fmt_s(k.compute_s)} "
            f"| {fmt_s(k.memory_s)} | {k.bottleneck} |")
    return "\n".join(rows)


def tuned_blocks_table(cache_path=None):
    """Autotune winners vs the static default blocks, per backend/bucket.

    Reads the tuned_blocks.json cache written by kernels/autotune.py (plus
    anything already registered in-process via roofline.register_tuned).
    """
    from . import roofline as rl
    if cache_path:
        rl.load_tuned(cache_path)
    rows = ["| kernel | backend | bucket | tuned blocks | tuned us | "
            "default blocks | default us | speedup |",
            "|---|---|---|---|---|---|---|---|"]
    if not rl.TUNED_KERNELS:
        rows.append("| (no autotune winners recorded — run "
                    "`benchmarks/run.py --autotune`) | | | | | | | |")
        return "\n".join(rows)

    def blk(cfg):
        return ",".join(f"{k}={v}" for k, v in sorted((cfg or {}).items()))

    for key in sorted(rl.TUNED_KERNELS):
        e = rl.TUNED_KERNELS[key]
        backend, _, rest = key.partition("/")
        _, _, bucket = rest.partition("/")
        us, dus = e.get("us"), e.get("default_us")
        rows.append("| {} | {} | {} | {} | {} | {} | {} | {} |".format(
            e.get("kernel", key), backend, bucket, blk(e.get("config")),
            f"{us:.1f}" if us else "-", blk(e.get("default_config")),
            f"{dus:.1f}" if dus else "-",
            f"{dus / us:.2f}x" if us and dus else "-"))
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "dryrun_results"))
    ap.add_argument("--tune-cache", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "tuned_blocks.json"))
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run (single pod 16x16)\n")
    print(dryrun_table(recs, "pod16x16"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(recs, "pod2x16x16"))
    print("\n## Roofline (single pod, per step)\n")
    print(roofline_table(recs))
    print("\n## Serving kernel roofline (scoring hot path, per call)\n")
    print(serving_kernel_table())
    print("\n## Tuned kernel blocks (autotune winners vs defaults)\n")
    print(tuned_blocks_table(args.tune_cache))


if __name__ == "__main__":
    main()
