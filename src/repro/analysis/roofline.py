"""Three-term roofline model from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = sum over collective ops of per-device bytes moved
                      over the slowest link they traverse

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the harness spec); cross-pod (the `pod` axis) goes over DCN at an
assumed 25 GB/s per host aggregate.

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the post-SPMD HLO text and sum operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, applying the standard ring-algorithm byte multipliers:

    all-reduce:      2 * size * (n-1)/n        (reduce-scatter + all-gather)
    all-gather:      size_out * (n-1)/n
    reduce-scatter:  size_in * (n-1)/n  (~= size_out * (n-1))
    all-to-all:      size * (n-1)/n
    collective-permute: size
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (in-pod)
DCN_BW = 25e9                # bytes/s / chip-pair aggregate (cross-pod)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:%|ROOT\s+%?)?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: Dict[str, float]         # logical output bytes by op kind
    moved_bytes: float                 # ring-model per-device bytes moved
    n_ops: int

    def to_json(self):
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    op_bytes: Dict[str, float] = {}
    moved = 0.0
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # count the -start, skip its completion marker
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 2
        n = max(n, 2)
        if kind == "all-reduce":
            b = 2.0 * size * (n - 1) / n
        elif kind == "all-gather":
            b = size * (n - 1) / n           # size = gathered output
        elif kind == "reduce-scatter":
            b = size * (n - 1)               # size = scattered output shard
        elif kind == "all-to-all":
            b = size * (n - 1) / n
        else:                                 # collective-permute
            b = size
        op_bytes[kind] = op_bytes.get(kind, 0.0) + size
        moved += b
        n_ops += 1
    return CollectiveStats(op_bytes=op_bytes, moved_bytes=moved, n_ops=n_ops)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6*N*D useful flops (per device share)
    useful_flops_ratio: float

    def to_json(self):
        return dataclasses.asdict(self)


def compute_roofline(flops: float, bytes_accessed: float,
                     coll: CollectiveStats, n_devices: int,
                     model_flops_global: float,
                     link_bw: float = ICI_BW) -> Roofline:
    """All inputs per-device except model_flops_global (whole step)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll.moved_bytes / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global / n_devices
    return Roofline(
        flops_per_device=flops, bytes_per_device=bytes_accessed,
        collective_bytes=coll.moved_bytes, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, bottleneck=bottleneck,
        model_flops=mf,
        useful_flops_ratio=(mf / flops) if flops else 0.0)


# ---------------------------------------------------------------------------
# Serving scoring kernels (kernels/survival_curves.py + engine matvecs):
# analytic per-call cost models so report.py covers the inference hot path.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KernelRoofline:
    name: str
    flops: float
    bytes_accessed: float
    compute_s: float
    memory_s: float
    intensity: float             # flops / byte
    bottleneck: str

    def to_json(self):
        return dataclasses.asdict(self)


def _cost_survival_curves(batch: int, grid: int) -> Dict[str, float]:
    """Fused S(t|x) panel: rank-1 outer product + exp, one HBM write of
    the (b, g) output; exp counted as one flop like the MXU ops."""
    return {"flops": 2.0 * batch * grid + batch,
            "bytes": 4.0 * (batch + grid + batch * grid)}


def _cost_risk_dense(batch: int, p: int) -> Dict[str, float]:
    """eta = X beta + exp: streams the (b, p) feature panel once."""
    return {"flops": 2.0 * batch * p + batch,
            "bytes": 4.0 * (batch * p + p + batch)}


def _cost_risk_sparse(batch: int, k: int) -> Dict[str, float]:
    """Support-gathered matvec: O(k) per request on the beam-search path."""
    return {"flops": 2.0 * batch * k + batch,
            "bytes": 4.0 * (batch * k + k + batch)}


SERVING_KERNELS = {
    "survival_curves": _cost_survival_curves,
    "risk_dense": _cost_risk_dense,
    "risk_sparse": _cost_risk_sparse,
}


def kernel_roofline(name: str, **shape) -> KernelRoofline:
    """Roofline terms for one registered serving kernel at a shape."""
    cost = SERVING_KERNELS[name](**shape)
    flops, nbytes = cost["flops"], cost["bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    return KernelRoofline(
        name=name, flops=flops, bytes_accessed=nbytes, compute_s=compute_s,
        memory_s=memory_s, intensity=flops / nbytes if nbytes else 0.0,
        bottleneck="compute" if compute_s >= memory_s else "memory")


# ---------------------------------------------------------------------------
# Tuned-block registry: the autotuner (kernels/autotune.py) registers each
# winner here, and report.py renders the tuned-vs-default table from it.
# Keys are "backend/kernel/bucket" — the same keys as the JSON tune cache.
# ---------------------------------------------------------------------------

TUNED_KERNELS: Dict[str, dict] = {}


def register_tuned(key: str, entry: dict) -> None:
    """Record one autotune winner: ``entry`` carries at least ``config``;
    timed entries also carry ``us``, ``default_config``, ``default_us``."""
    TUNED_KERNELS[key] = dict(entry)


def load_tuned(path: str) -> Dict[str, dict]:
    """Populate the registry from a tuned_blocks.json cache file (no-op on
    a missing/corrupt file — the registry just stays as-is)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return TUNED_KERNELS
    for key, entry in (data.get("entries") or {}).items():
        if isinstance(entry, dict):
            register_tuned(key, entry)
    return TUNED_KERNELS


def model_flops_for(cfg, shape, n_params_active: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active
    params; D = tokens processed this step."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.global_batch  # decode: 1 tok/seq


def active_params(cfg) -> int:
    """Active (per-token) parameter count — MoE counts top-k experts only."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    hd, h, kh = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    attn = d * hd * (h + 2 * kh) + h * hd * d
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        conv_c = d_in + 2 * cfg.ssm_state
        m = (d * (2 * d_in + 2 * cfg.ssm_state + nh)   # in_proj
             + 4 * conv_c + d_in * d)                  # conv + out_proj
        per_layer = m
        total = L * per_layer
        if cfg.family == "hybrid":
            shared = attn + 3 * d * ff
            total += (L // cfg.shared_attn_every) * shared
    elif cfg.n_experts > 0:
        ffn = cfg.n_experts_per_tok * 3 * d * ff + d * cfg.n_experts
        total = L * (attn + ffn)
    else:
        total = L * (attn + 3 * d * ff)
        if cfg.family == "encdec":
            total += cfg.encoder_layers * (attn + 3 * d * ff) \
                + L * (attn)  # cross attention
    total += cfg.vocab_padded * d * (1 if cfg.tie_embeddings else 2)
    return int(total)
