"""k-fold cross-validation driver (the paper's evaluation protocol:
5-fold, mean +/- std of CIndex/IBS/loss per support size)."""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from ..core import cox
from . import metrics


def kfold_indices(n: int, k: int = 5, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [(np.concatenate([perm[j::k] for j in range(k) if j != i]),
             perm[i::k]) for i in range(k)]


def cross_validate(x: np.ndarray, t: np.ndarray, delta: np.ndarray,
                   fit_fn: Callable, k: int = 5, seed: int = 0
                   ) -> Dict[str, np.ndarray]:
    """fit_fn(CoxData_train) -> beta (p,). Returns mean/std of CIndex and
    IBS over folds (the paper's Figs. 3/4 protocol)."""
    cis, ibss, losses = [], [], []
    for tr, te in kfold_indices(len(t), k, seed):
        data_tr = cox.prepare(x[tr], t[tr], delta[tr])
        beta = np.asarray(fit_fn(data_tr))
        eta_tr = x[tr] @ beta
        eta_te = x[te] @ beta
        cis.append(metrics.cindex(t[te], delta[te], eta_te))
        ibss.append(metrics.ibs(t[tr], delta[tr], eta_tr,
                                t[te], delta[te], eta_te))
        data_te = cox.prepare(x[te], t[te], delta[te])
        losses.append(float(cox.loss_from_eta(
            data_te, data_te.x @ beta)))
    return {"cindex_mean": np.mean(cis), "cindex_std": np.std(cis),
            "ibs_mean": np.mean(ibss), "ibs_std": np.std(ibss),
            "loss_mean": np.mean(losses), "loss_std": np.std(losses)}
