"""Survival evaluation metrics (Appendix C.2): Harrell's CIndex, Integrated
Brier Score with IPCW weighting and a Breslow baseline-hazard estimator, and
support-recovery precision/recall/F1. Host-side numpy (evaluation only)."""
from __future__ import annotations

import numpy as np


def cindex(t: np.ndarray, delta: np.ndarray, risk: np.ndarray,
           chunk: int = 4096) -> float:
    """Harrell's concordance index. Comparable pair: t_i < t_j with
    delta_i = 1; concordant if risk_i > risk_j; risk ties count 1/2.

    Pairs are enumerated in row chunks of ``chunk`` samples so peak host
    memory is O(chunk * n) instead of O(n^2); the counts are bitwise the
    same as the full broadcast."""
    t = np.asarray(t, np.float64)
    delta = np.asarray(delta).astype(bool)
    risk = np.asarray(risk, np.float64)
    n = len(t)
    n_comp = 0
    score = 0.0
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        comparable = (t[lo:hi, None] < t[None, :]) & delta[lo:hi, None]
        conc = (risk[lo:hi, None] > risk[None, :]) & comparable
        ties = np.isclose(risk[lo:hi, None], risk[None, :]) & comparable
        n_comp += int(comparable.sum())
        score += conc.sum() + 0.5 * ties.sum()
    if n_comp == 0:
        return 0.5
    return float(score / n_comp)


def km_censoring(t: np.ndarray, delta: np.ndarray):
    """Kaplan-Meier estimate of the *censoring* survival G(t) (IPCW)."""
    t = np.asarray(t, np.float64)
    cens = 1.0 - np.asarray(delta, np.float64)
    order = np.argsort(t)
    ts, cs = t[order], cens[order]
    uniq, start = np.unique(ts, return_index=True)
    n = len(ts)
    at_risk = n - start
    d = np.add.reduceat(cs, start)
    surv = np.cumprod(1.0 - d / np.maximum(at_risk, 1))

    def g(query):
        idx = np.searchsorted(uniq, query, side="right") - 1
        out = np.where(idx >= 0, surv[np.clip(idx, 0, len(surv) - 1)], 1.0)
        return np.maximum(out, 1e-8)

    return g


def breslow_baseline(t_train, delta_train, eta_train):
    """Breslow cumulative baseline hazard H0(t) = sum_{t_i<=t} d_i / S0_i."""
    t_train = np.asarray(t_train, np.float64)
    order = np.argsort(t_train)
    ts = t_train[order]
    ds = np.asarray(delta_train, np.float64)[order]
    es = np.asarray(eta_train, np.float64)[order]
    w = np.exp(es - es.max())
    s0 = np.cumsum(w[::-1])[::-1]
    # Breslow ties: risk set starts at first tied index
    first = np.searchsorted(ts, ts, side="left")
    # s0 was formed from stabilized w = exp(eta - max); true S0 = s0 * e^max,
    # so divide the increments by e^max to undo the stabilization.
    h_inc = ds / s0[first]
    h0 = np.cumsum(h_inc) * np.exp(-es.max())

    def h(query):
        idx = np.searchsorted(ts, query, side="right") - 1
        return np.where(idx >= 0, h0[np.clip(idx, 0, len(h0) - 1)], 0.0)

    return h


def ibs(t_train, delta_train, eta_train, t_test, delta_test, eta_test,
        n_grid: int = 100) -> float:
    """Integrated Brier Score (Graf et al. 1999) with IPCW weights.

    S(t|x) = exp(-H0(t) * exp(eta_x)) via the Breslow estimator on train.
    """
    h0 = breslow_baseline(t_train, delta_train, eta_train)
    g = km_censoring(t_train, delta_train)
    t_test = np.asarray(t_test, np.float64)
    delta_test = np.asarray(delta_test, np.float64)
    eta_test = np.asarray(eta_test, np.float64)
    lo, hi = np.quantile(t_test, 0.05), np.quantile(t_test, 0.95)
    grid = np.linspace(lo, hi, n_grid)
    scores = []
    for tt in grid:
        s = np.exp(-h0(tt) * np.exp(np.clip(eta_test, -30, 30)))
        died = (t_test <= tt) & (delta_test > 0)
        alive = t_test > tt
        bs = (np.where(died, (0.0 - s) ** 2 / g(np.minimum(t_test, tt)), 0.0)
              + np.where(alive, (1.0 - s) ** 2 / g(tt), 0.0))
        scores.append(bs.mean())
    return float(np.trapezoid(scores, grid) / (hi - lo))


def support_f1(beta_star: np.ndarray, beta_hat: np.ndarray,
               tol: float = 1e-8):
    """(precision, recall, f1) of support recovery (Appendix C.2)."""
    s_star = set(np.flatnonzero(np.abs(beta_star) > tol).tolist())
    s_hat = set(np.flatnonzero(np.abs(beta_hat) > tol).tolist())
    if not s_hat or not s_star:
        return 0.0, 0.0, 0.0
    inter = len(s_star & s_hat)
    prec = inter / len(s_hat)
    rec = inter / len(s_star)
    f1 = 0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec)
    return prec, rec, f1
