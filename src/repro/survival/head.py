"""Deep-survival head: the paper's CPH objective as a first-class training
objective for any backbone in the pool (DeepSurv-style).

The batch is the risk-set universe: risk scores eta_i come from the pooled
final hidden state, the batch is sorted by observed time *inside the step*
(argsort is jit-able), and the loss is the exact Breslow negative log
partial likelihood from repro.core.cox — so the gradient flowing into the
backbone is the same eta-space gradient (w*A - delta) the paper analyzes.

`sparse_refit` then applies the paper's beam-search CD on frozen pooled
features to produce an interpretable sparse linear head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import beam, cox
from ..models.model import Model

Array = jax.Array


def init_cox_head(rng, d_model: int):
    return {"w": jax.random.normal(rng, (d_model, 1), jnp.float32) * 0.01,
            "b": jnp.zeros((), jnp.float32)}


def cox_partial_likelihood(eta: Array, time: Array, event: Array) -> Array:
    """Exact CPH loss of a batch, sorted on the fly (Breslow ties)."""
    order = jnp.argsort(time, stable=True)
    ts = time[order]
    risk_start = jnp.searchsorted(ts, ts, side="left").astype(jnp.int32)
    tie_end = (jnp.searchsorted(ts, ts, side="right") - 1).astype(jnp.int32)
    data = cox.CoxData(x=jnp.zeros((time.shape[0], 0), eta.dtype),
                       delta=event[order].astype(eta.dtype),
                       risk_start=risk_start, tie_end=tie_end)
    return cox.loss_from_eta(data, eta[order]) \
        / jnp.maximum(jnp.sum(event), 1.0)


def cox_loss(model: Model, params, batch):
    """Survival objective for trainer.make_train_step(objective='cox')."""
    eta, aux = model.risk_scores(params, batch)
    loss = cox_partial_likelihood(eta.astype(jnp.float32),
                                  batch["time"], batch["event"])
    return loss + 0.01 * aux, {"cox_nll": loss, "aux": aux}


def pooled_features(model: Model, params, batch) -> Array:
    hidden, _, _ = model.hidden_states(params, batch, remat=False)
    return hidden.mean(axis=1).astype(jnp.float32)


def sparse_refit(features: np.ndarray, time: np.ndarray, event: np.ndarray,
                 k: int, beam_width: int = 4):
    """Beam-search L0-constrained CPH on frozen backbone features —
    the paper's variable selection producing an interpretable sparse head."""
    data = cox.prepare(jnp.asarray(features), jnp.asarray(time),
                       jnp.asarray(event))
    return beam.beam_search(data, k=k, beam_width=beam_width)
