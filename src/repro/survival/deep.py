"""FastCPH-style deep survival: zoo backbone -> exact CPH head -> paper
solver refit -> serving artifact.

The end-to-end pipeline the revived model zoo unblocks:

  1. **Train** a backbone from the architecture registry (default the
     reduced mamba2-130m config) under ``survival/head.cox_loss`` — the
     exact Breslow partial likelihood in eta-space, so the gradient into
     the backbone is the (w*A - delta) eta-gradient the paper analyzes.
  2. **Freeze + featurize**: mean-pooled final hidden states become the
     feature matrix of a linear CPH problem.
  3. **Sparse refit** with the paper's surrogate/beam-search coordinate
     descent (``head.sparse_refit``) — an interpretable k-sparse head on
     the learned representation, FastCPH's "last layer trained by the
     exact solver" recipe.
  4. **Export** a ``serving.SurvivalModel`` artifact: the sparse beta plus
     a Breslow baseline cumulative hazard fit on the *training* features,
     so the artifact loads through ``serving.ModelRegistry`` and scores
     through ``RiskService`` exactly like a linear model — the serving
     stack gains deep models without a line of new serving code. Request
     features are pooled embeddings, produced by ``make_featurizer``.

``run()`` chains all four and reports held-out c-indexes for both the
deep head (backbone risk scores) and the sparse refit head.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..configs.base import ModelConfig, TrainConfig
from ..core.beam import BeamResult
from ..data.pipeline import SurvivalTextStream
from ..models import build_model
from ..models.model import Model
from ..serving.artifacts import SurvivalModel, fit_survival_model
from ..train.loop import run_loop
from ..train.optimizer import init_opt_state
from ..train.trainer import TrainState, make_train_step
from . import metrics
from .head import init_cox_head, pooled_features, sparse_refit


@dataclasses.dataclass
class DeepSurvivalConfig:
    """Knobs for the train -> refit -> export pipeline."""

    arch: str = "mamba2-130m"
    full: bool = False           # ~100M config instead of the CPU-sized one
    steps: int = 150
    batch: int = 32
    seq: int = 48
    learning_rate: float = 2e-3
    warmup_steps: int = 20
    seed: int = 0
    k: int = 8                   # sparse-head support size (<= d_model)
    beam_width: int = 4
    refit_batches: int = 4       # held-out batches for refit + eval
    grid_size: int = 64          # artifact time-grid resolution
    log_every: int = 25


@dataclasses.dataclass
class DeepSurvivalResult:
    """Everything the pipeline produced, ready for serving or analysis."""

    cfg: ModelConfig
    state: TrainState
    losses: List[float]
    features: np.ndarray         # (n_eval, d_model) frozen pooled features
    times: np.ndarray
    events: np.ndarray
    risks_deep: np.ndarray       # backbone head risk on the eval batches
    beam: BeamResult
    beta: np.ndarray             # (d_model,) dense sparse-refit coefficients
    artifact: SurvivalModel
    cindex_deep: float
    cindex_sparse: float

    @property
    def nnz(self) -> int:
        return int((np.abs(self.beta) > 1e-8).sum())


def model_config(dcfg: DeepSurvivalConfig) -> ModelConfig:
    """Resolve the backbone config: registry entry at full scale, or the
    CPU-sized reduction (the shape every test/smoke path runs)."""
    cfg = get_config(dcfg.arch)
    if dcfg.full:
        return cfg.scaled(n_layers=12, vocab_size=2048)
    cfg = reduced_config(cfg)
    if cfg.family in ("ssm", "hybrid"):
        cfg = cfg.scaled(n_layers=4, d_model=128, vocab_size=512,
                         ssm_state=32)
    return cfg


def init_state(model: Model, rng_seed: int = 0) -> TrainState:
    """Backbone params + CPH head, wrapped in a fresh optimizer state."""
    params = model.init_params(jax.random.PRNGKey(rng_seed))
    params["cox_head"] = init_cox_head(jax.random.PRNGKey(rng_seed + 1),
                                       model.cfg.d_model)
    return TrainState(params=params, opt=init_opt_state(params))


def train_backbone(model: Model, dcfg: DeepSurvivalConfig,
                   stream: Optional[SurvivalTextStream] = None,
                   state: Optional[TrainState] = None,
                   ) -> Tuple[TrainState, List[float], SurvivalTextStream]:
    """Steps 1: fit the backbone under the exact CPH objective."""
    cfg = model.cfg
    if stream is None:
        stream = SurvivalTextStream(cfg.vocab_size, dcfg.seq, dcfg.batch,
                                    seed=dcfg.seed)
    if state is None:
        state = init_state(model, dcfg.seed)
    tcfg = TrainConfig(learning_rate=dcfg.learning_rate,
                       warmup_steps=dcfg.warmup_steps,
                       total_steps=dcfg.steps)
    step_fn = jax.jit(make_train_step(model, tcfg, objective="cox"))
    state, losses = run_loop(step_fn, state, stream, dcfg.steps,
                             log_every=dcfg.log_every,
                             log_prefix="[deep]")
    return state, losses, stream


def make_featurizer(model: Model):
    """Jitted ``(params, batch) -> (risk (b,), features (b, d_model))`` —
    the request-time transform that turns raw sequences into the feature
    vectors a deep ``SurvivalModel`` artifact scores."""

    @jax.jit
    def featurize(params, batch):
        risk, _ = model.risk_scores(params, batch)
        feats = pooled_features(model, params, batch)
        return risk.astype(np.float32), feats

    return featurize


def collect_features(model: Model, state: TrainState,
                     stream: SurvivalTextStream, start_step: int,
                     n_batches: int) -> Dict[str, np.ndarray]:
    """Steps 2: frozen pooled features + labels over held-out batches."""
    featurize = make_featurizer(model)
    feats, times, events, risks = [], [], [], []
    for step in range(start_step, start_step + n_batches):
        b = stream.batch_for_step(step)
        r, f = featurize(state.params, b)
        risks.append(np.asarray(r))
        feats.append(np.asarray(f))
        times.append(b["time"])
        events.append(b["event"])
    return {"features": np.concatenate(feats),
            "time": np.concatenate(times),
            "event": np.concatenate(events),
            "risk_deep": np.concatenate(risks)}


def refit_and_export(features: np.ndarray, t: np.ndarray, e: np.ndarray,
                     *, k: int, beam_width: int = 4, grid_size: int = 64,
                     ) -> Tuple[BeamResult, np.ndarray, SurvivalModel]:
    """Steps 3+4: beam-search sparse head on frozen features, then the
    serving artifact (sparse beta + Breslow baseline on those features).

    ``fit_survival_model`` detects the sparse support itself, so the
    artifact carries the O(k) fast-path fields the engine uses.
    """
    beam = sparse_refit(features, t, e, k=k, beam_width=beam_width)
    beta = np.asarray(beam.betas[-1], np.float32)
    artifact = fit_survival_model(features, t, e, beta,
                                  grid_size=grid_size)
    return beam, beta, artifact


def run(dcfg: Optional[DeepSurvivalConfig] = None,
        **overrides: Any) -> DeepSurvivalResult:
    """The whole pipeline; ``overrides`` patch ``DeepSurvivalConfig``."""
    if dcfg is None:
        dcfg = DeepSurvivalConfig(**overrides)
    elif overrides:
        dcfg = dataclasses.replace(dcfg, **overrides)
    cfg = model_config(dcfg)
    model = build_model(cfg)
    state, losses, stream = train_backbone(model, dcfg)
    held = collect_features(model, state, stream, dcfg.steps,
                            dcfg.refit_batches)
    k = min(dcfg.k, max(cfg.d_model // 4, 1))
    beam, beta, artifact = refit_and_export(
        held["features"], held["time"], held["event"],
        k=k, beam_width=dcfg.beam_width, grid_size=dcfg.grid_size)
    ci_deep = metrics.cindex(held["time"], held["event"],
                             held["risk_deep"])
    ci_sparse = metrics.cindex(held["time"], held["event"],
                               held["features"] @ beta)
    return DeepSurvivalResult(
        cfg=cfg, state=state, losses=losses,
        features=held["features"], times=held["time"],
        events=held["event"], risks_deep=held["risk_deep"],
        beam=beam, beta=beta, artifact=artifact,
        cindex_deep=float(ci_deep), cindex_sparse=float(ci_sparse))
