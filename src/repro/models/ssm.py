"""Mamba2 SSD (state-space duality) block — chunked dual form for
training/prefill, O(1)-state recurrent step for decode.

Recurrence per head (Mamba2, arXiv:2405.21060):
    h_t = exp(dt_t A) h_{t-1} + dt_t * x_t B_t^T        h: (hd, N)
    y_t = C_t h_t + D x_t
Chunked (SSD) evaluation over chunks of length Q:
    intra-chunk: masked (Q x Q) quadratic form on the MXU
    inter-chunk: per-chunk states passed through a lax.scan
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


def init_mamba2(rng, d_model: int, d_state: int, head_dim: int = 64,
                expand: int = 2, conv_width: int = 4, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    n_groups = 1
    k = jax.random.split(rng, 5)
    s = d_model ** -0.5
    d_conv = d_inner + 2 * n_groups * d_state
    return {
        # projects to [z (d_inner), x (d_inner), B (g*N), C (g*N), dt (H)]
        "w_in": jax.random.normal(
            k[0], (d_model, 2 * d_inner + 2 * n_groups * d_state + n_heads),
            dtype) * s,
        "conv_w": jax.random.normal(k[1], (conv_width, d_conv), dtype) * 0.2,
        "conv_b": jnp.zeros((d_conv,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": jax.random.normal(k[2], (d_inner, d_model), dtype)
        * (d_inner ** -0.5),
    }


class SSMState(NamedTuple):
    conv: Array   # (B, conv_width-1, d_conv) rolling conv inputs
    ssm: Array    # (B, H, hd, N) recurrent state


def _split(params, d_model: int, d_state: int, head_dim: int, expand: int):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    n_groups = 1
    return d_inner, n_heads, n_groups


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    wdt = xbc.dtype
    width = w.shape[0]
    xp = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b).astype(wdt)


def mamba2_forward(params, x: Array, *, d_state: int, head_dim: int = 64,
                   expand: int = 2, chunk: int = 256,
                   return_state: bool = False):
    """x: (B, S, D) -> (y: (B, S, D)[, final SSMState])."""
    b, s, d_model = x.shape
    d_inner, n_heads, n_groups = _split(params, d_model, d_state, head_dim,
                                        expand)
    proj = x @ params["w_in"]
    z, xbc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * n_groups * d_state], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, bb, cc = jnp.split(xbc, [d_inner, d_inner + n_groups * d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])                                      # (H,)

    xh = xs.reshape(b, s, n_heads, head_dim)
    bb = bb.reshape(b, s, n_groups, d_state)
    cc = cc.reshape(b, s, n_groups, d_state)

    y, st = _ssd_chunked(xh, dt, a, bb, cc, chunk)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(g32 * g32, axis=-1, keepdims=True)
    g = (g32 * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype) \
        * params["norm_scale"]
    out = g @ params["w_out"]
    if return_state:
        conv_tail = jnp.pad(
            (x @ params["w_in"])[:, :, d_inner:2 * d_inner
                                 + 2 * n_groups * d_state],
            ((0, 0), (max(0, 3 - s), 0), (0, 0)))[:, -3:, :]
        return out, SSMState(conv=conv_tail, ssm=st)
    return out


def _ssd_chunked(xh, dt, a, bb, cc, chunk):
    """Chunked SSD. xh: (B,S,H,hd); dt: (B,S,H); a: (H,);
    bb/cc: (B,S,G,N) with G=1. Returns (y (B,S,H,hd) f32, state (B,H,hd,N))."""
    b, s, h, hd = xh.shape
    n = bb.shape[-1]
    q = chunk
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xc = xh.reshape(b, nc, q, h, hd).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    bc = bb.reshape(b, nc, q, n).astype(jnp.float32)   # G=1 squeezed
    ccx = cc.reshape(b, nc, q, n).astype(jnp.float32)

    la = dtc * a  # (B,nc,q,H) log decay per step
    cum = jnp.cumsum(la, axis=2)  # L_t
    total = cum[:, :, -1:, :]     # L_Q

    # intra-chunk: y[t] = sum_{s<=t} C_t.B_s exp(L_t - L_s) dt_s x_s
    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]
    # decay(t,s) = exp(L_t - L_s) for s <= t
    dec = jnp.exp(jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :],
                           -60.0, 0.0))              # (B,nc,q,q,H)
    cb = jnp.einsum("bcqn,bcsn->bcqs", ccx, bc)      # (B,nc,q,q)
    w_ = cb[..., None] * dec * dtc[:, :, None, :, :] \
        * causal[None, None, :, :, None]
    y_intra = jnp.einsum("bcqsh,bcshd->bcqhd", w_, xc)

    # chunk-level input state: sum_s exp(L_Q - L_s) dt_s x_s B_s^T
    decq = jnp.exp(jnp.clip(total - cum, -60.0, 0.0))  # (B,nc,q,H)
    sin = jnp.einsum("bcqh,bcqhd,bcqn->bchdn", decq * dtc, xc, bc)

    # scan chunk states: st_c = exp(L_Q_c) st_{c-1} + sin_c
    chunk_decay = jnp.exp(jnp.clip(total[:, :, 0, :], -60.0, None))  # (B,nc,H)

    def scan_fn(carry, inp):
        sin_c, dec_c = inp
        new = carry * dec_c[..., None, None] + sin_c
        return new, carry  # emit the INCOMING state for chunk c

    st0 = jnp.zeros((b, h, hd, n), jnp.float32)
    stf, st_in = jax.lax.scan(
        scan_fn, st0,
        (jnp.moveaxis(sin, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    st_in = jnp.moveaxis(st_in, 0, 1)  # (B,nc,H,hd,N)

    # inter-chunk: y[t] += C_t (exp(L_t) st_in)
    y_inter = jnp.einsum("bcqn,bcqh,bchdn->bcqhd",
                         ccx, jnp.exp(jnp.clip(cum, -60.0, 0.0)), st_in)
    y = (y_intra + y_inter).reshape(b, nc * q, h, hd)[:, :s]
    return y, stf


def mamba2_decode_step(params, x: Array, state: SSMState, *, d_state: int,
                       head_dim: int = 64, expand: int = 2):
    """Single-token recurrent step. x: (B, 1, D)."""
    b, _, d_model = x.shape
    d_inner, n_heads, n_groups = _split(params, d_model, d_state, head_dim,
                                        expand)
    proj = x @ params["w_in"]
    z, xbc_new, dt = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * n_groups * d_state], axis=-1)
    # rolling conv window: state.conv holds previous (width-1) inputs
    win = jnp.concatenate([state.conv, xbc_new], axis=1)  # (B, W, C)
    w = params["conv_w"]
    out = (win * w[None, :, :]).sum(axis=1, keepdims=True)
    xbc = jax.nn.silu(out + params["conv_b"]).astype(x.dtype)
    new_conv = win[:, 1:, :]

    xs, bb, cc = jnp.split(xbc, [d_inner, d_inner + n_groups * d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = -jnp.exp(params["a_log"])
    xhh = xs.reshape(b, n_heads, head_dim).astype(jnp.float32)
    bvec = bb.reshape(b, d_state).astype(jnp.float32)
    cvec = cc.reshape(b, d_state).astype(jnp.float32)

    dec = jnp.exp(dt * a)  # (B,H)
    upd = jnp.einsum("bh,bhd,bn->bhdn", dt, xhh, bvec)
    new_ssm = state.ssm * dec[..., None, None] + upd
    y = jnp.einsum("bhdn,bn->bhd", new_ssm, cvec) \
        + params["d_skip"][None, :, None] * xhh
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(g32 * g32, axis=-1, keepdims=True)
    g = (g32 * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype) \
        * params["norm_scale"]
    return g @ params["w_out"], SSMState(conv=new_conv, ssm=new_ssm)
