"""Model dispatch: one functional `Model` facade over every family in the
pool (dense / moe / vlm decoder-only, ssm, hybrid, encdec).

All public entry points are jit-friendly pure functions of (params, batch)
or (params, cache, tokens); `make_input_specs` produces the
ShapeDtypeStruct stand-ins the dry-run lowers against.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeSpec
from . import layers, pspec, ssm, transformer as tf

Array = jax.Array


class SSMCache(NamedTuple):
    conv: Array    # (L, B, W-1, C)
    state: Array   # (L, B, H, hd, N)
    length: Array  # (B,)


class HybridCache(NamedTuple):
    conv: Array    # (L, B, W-1, C)
    state: Array   # (L, B, H, hd, N)
    k: Array       # (G, B, S, KH, hd) shared-attn caches per application
    v: Array
    length: Array


class EncDecCache(NamedTuple):
    k: Array       # (L, B, S_dec, KH, hd) decoder self-attention
    v: Array
    xk: Array      # (L, B, S_src, KH, hd) precomputed cross K/V
    xv: Array
    length: Array


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _maybe_remat(body, remat):
    """remat: False | True/'nothing' (recompute all) | 'dots' (save matmul
    outputs — the capacity/traffic middle ground of §Perf B6)."""
    if remat is False or remat is None:
        return body
    policy = jax.checkpoint_policies.dots_saveable if remat == "dots" \
        else jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(body, policy=policy)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dt = _dtype(cfg)

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init_params(self, rng) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.dt
        keys = jax.random.split(rng, 8)
        p: Dict[str, Any] = {
            "embed": jax.random.normal(
                keys[0], (cfg.vocab_padded, cfg.d_model), dt) * 0.02,
            "final_norm": layers.init_rmsnorm(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = jax.random.normal(
                keys[1], (cfg.d_model, cfg.vocab_padded), dt) \
                * cfg.d_model ** -0.5

        if cfg.family in ("dense", "moe", "vlm"):
            p["layers"] = jax.vmap(
                lambda r: tf.init_block(r, cfg, dt))(
                    jax.random.split(keys[2], cfg.n_layers))
        elif cfg.family == "ssm":
            p["layers"] = jax.vmap(lambda r: {
                "ln": layers.init_rmsnorm(cfg.d_model, dt),
                "mamba": ssm.init_mamba2(r, cfg.d_model, cfg.ssm_state,
                                         cfg.ssm_head_dim, cfg.ssm_expand,
                                         dtype=dt),
            })(jax.random.split(keys[2], cfg.n_layers))
        elif cfg.family == "hybrid":
            p["layers"] = jax.vmap(lambda r: {
                "ln": layers.init_rmsnorm(cfg.d_model, dt),
                "mamba": ssm.init_mamba2(r, cfg.d_model, cfg.ssm_state,
                                         cfg.ssm_head_dim, cfg.ssm_expand,
                                         dtype=dt),
            })(jax.random.split(keys[2], cfg.n_layers))
            p["shared"] = tf.init_block(keys[3], cfg, dt)
        elif cfg.family == "encdec":
            p["enc_layers"] = jax.vmap(
                lambda r: tf.init_block(r, cfg, dt))(
                    jax.random.split(keys[2], cfg.encoder_layers))
            p["enc_norm"] = layers.init_rmsnorm(cfg.d_model, dt)
            p["layers"] = jax.vmap(
                lambda r: tf.init_block(r, cfg, dt, cross_attn=True))(
                    jax.random.split(keys[3], cfg.n_layers))
        else:
            raise ValueError(cfg.family)
        return p

    # ------------------------------------------------------------------
    # Embedding / logits
    # ------------------------------------------------------------------
    def _embed_in(self, params, batch) -> Array:
        if "embeds" in batch:
            x = batch["embeds"].astype(self.dt)
        else:
            x = params["embed"][batch["tokens"]]
        if self.cfg.name.startswith("gemma"):
            x = x * jnp.asarray(self.cfg.d_model ** 0.5, self.dt)
        return pspec.constrain(x, "dp", None, None)

    def _logits(self, params, hidden: Array) -> Array:
        head = params["embed"].T if self.cfg.tie_embeddings \
            else params["lm_head"]
        logits = hidden @ head
        spec = ["dp"] + [None] * (logits.ndim - 2) + ["model"]
        logits = pspec.constrain(logits, *spec)
        v = self.cfg.vocab_size
        if self.cfg.vocab_padded != v:
            pad_mask = jnp.arange(self.cfg.vocab_padded) >= v
            logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32))
        return logits

    def _positions(self, batch, seq: int, bsz: int) -> Array:
        if "positions" in batch:
            return batch["positions"]
        return jnp.broadcast_to(jnp.arange(seq)[None, :], (bsz, seq))

    # ------------------------------------------------------------------
    # Hidden-state stacks (train / prefill)
    # ------------------------------------------------------------------
    def _decoder_stack(self, params, x, positions, want_kv: bool,
                       remat: bool = True):
        cfg = self.cfg
        windows, thetas = tf.attention_pattern(cfg, cfg.n_layers)

        def body(carry, xs):
            h, aux = carry
            p_l, w_l, th_l = xs
            h, a, kv = tf.block_forward(p_l, cfg, h, positions, w_l, th_l,
                                        want_kv=want_kv)
            return (h, aux + a), kv

        body = _maybe_remat(body, remat)
        (x, aux), kvs = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], windows, thetas), unroll=cfg.scan_unroll)
        return x, aux, kvs

    def _ssm_stack(self, params, x, want_state: bool, remat: bool = True):
        cfg = self.cfg

        def body(carry, p_l):
            h = carry
            y = ssm.mamba2_forward(
                p_l["mamba"], layers.rmsnorm(p_l["ln"], h, cfg.rms_eps),
                d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, chunk=cfg.ssm_chunk,
                return_state=want_state)
            if want_state:
                y, st = y
                return pspec.constrain(h + y, "dp", None, None), st
            return pspec.constrain(h + y, "dp", None, None), None

        body = _maybe_remat(body, remat)
        x, states = jax.lax.scan(body, x, params["layers"],
                                 unroll=cfg.scan_unroll)
        return x, states

    def _hybrid_stack(self, params, x, positions, want_kv: bool,
                      remat: bool = True):
        """Zamba2: groups of `shared_attn_every` mamba layers, with the
        SHARED transformer block (one param set) applied after each group."""
        cfg = self.cfg
        per = cfg.shared_attn_every
        g = cfg.n_layers // per
        grouped = jax.tree.map(
            lambda a: a.reshape(g, per, *a.shape[1:]), params["layers"])
        shared = params["shared"]
        win = jnp.asarray(-1, jnp.int32)
        theta = jnp.asarray(cfg.rope_theta, jnp.float32)

        def group_body(carry, p_g):
            h = carry

            def inner(hh, p_l):
                y = ssm.mamba2_forward(
                    p_l["mamba"], layers.rmsnorm(p_l["ln"], hh, cfg.rms_eps),
                    d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                    expand=cfg.ssm_expand, chunk=cfg.ssm_chunk,
                    return_state=want_kv)
                if want_kv:
                    y, st = y
                    return pspec.constrain(hh + y, "dp", None, None), \
                        (st.conv, st.ssm)
                return pspec.constrain(hh + y, "dp", None, None), None

            h, states = jax.lax.scan(inner, h, p_g)
            h, _, kv = tf.block_forward(shared, cfg, h, positions, win,
                                        theta, want_kv=want_kv)
            return h, (kv, states)

        group_body = _maybe_remat(group_body, remat)
        x, (kvs, states) = jax.lax.scan(group_body, x, grouped,
                                        unroll=cfg.scan_unroll)
        return x, (kvs, states)

    def _encoder(self, params, src: Array, remat: bool = True):
        cfg = self.cfg
        pos = jnp.broadcast_to(jnp.arange(src.shape[1])[None, :],
                               src.shape[:2])
        win = jnp.asarray(-1, jnp.int32)
        theta = jnp.asarray(cfg.rope_theta, jnp.float32)

        def body(h, p_l):
            h, _, _ = tf.block_forward(p_l, cfg, h, pos, win, theta,
                                       causal=False)
            return h, None

        body = _maybe_remat(body, remat)
        h, _ = jax.lax.scan(body, src.astype(self.dt), params["enc_layers"],
                            unroll=cfg.scan_unroll)
        return layers.rmsnorm(params["enc_norm"], h, cfg.rms_eps)

    def _decoder_cross_stack(self, params, x, enc_out, want_kv: bool,
                             remat: bool = True):
        cfg = self.cfg
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :], x.shape[:2])
        win = jnp.asarray(-1, jnp.int32)
        theta = jnp.asarray(cfg.rope_theta, jnp.float32)

        def body(h, p_l):
            h, _, kv = tf.block_forward(p_l, cfg, h, pos, win, theta,
                                        enc_out=enc_out, want_kv=want_kv)
            return h, kv

        body = _maybe_remat(body, remat)
        return jax.lax.scan(body, x, params["layers"],
                            unroll=cfg.scan_unroll)

    def hidden_states(self, params, batch, want_cache: bool = False,
                      remat: bool = True):
        """(hidden (B,S,D), aux, cache_parts) for train/prefill."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        cache_parts = None
        if cfg.family in ("dense", "moe", "vlm"):
            x = self._embed_in(params, batch)
            pos = self._positions(batch, x.shape[1], x.shape[0])
            x, aux, cache_parts = self._decoder_stack(params, x, pos,
                                                      want_cache, remat)
        elif cfg.family == "ssm":
            x = self._embed_in(params, batch)
            x, cache_parts = self._ssm_stack(params, x, want_cache, remat)
        elif cfg.family == "hybrid":
            x = self._embed_in(params, batch)
            pos = self._positions(batch, x.shape[1], x.shape[0])
            x, cache_parts = self._hybrid_stack(params, x, pos, want_cache,
                                                remat)
        elif cfg.family == "encdec":
            enc = self._encoder(params, batch["src_embeds"], remat)
            x = params["embed"][batch["tokens"]]
            x, cache_parts = self._decoder_cross_stack(params, x, enc,
                                                       want_cache, remat)
            cache_parts = (cache_parts, enc)
        return layers.rmsnorm(params["final_norm"], x, cfg.rms_eps), aux, \
            cache_parts

    # ------------------------------------------------------------------
    # Objectives
    # ------------------------------------------------------------------
    def loss_lm(self, params, batch, remat: bool = True):
        hidden, aux, _ = self.hidden_states(params, batch, remat=remat)
        logits = self._logits(params, hidden).astype(jnp.float32)
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        ce = (lse - gold).mean()
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    def risk_scores(self, params, batch, remat: bool = True):
        """Deep-survival head: mean-pool final hidden -> risk (B,)."""
        hidden, aux, _ = self.hidden_states(params, batch, remat=remat)
        pooled = hidden.mean(axis=1).astype(jnp.float32)
        return pooled @ params["cox_head"]["w"][:, 0] \
            + params["cox_head"]["b"], aux

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def prefill(self, params, batch, max_len: int = 0):
        """Full-sequence forward that also builds the decode cache.

        ``max_len``: cache capacity (room for decode); defaults to S + 128.
        SWA rolling caches are always window-sized.
        """
        cfg = self.cfg

        def grow(kv, seq):  # pad seq axis (axis=2 of (L,B,S,KH,hd))
            cap = max_len if max_len > 0 else seq + 128
            if cfg.sliding_window > 0:
                return kv  # rolling buffer: fixed window capacity
            pad = max(cap - kv.shape[2], 0)
            return jnp.pad(kv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) \
                if pad else kv

        hidden, _, parts = self.hidden_states(params, batch, want_cache=True,
                                              remat=False)
        logits = self._logits(params, hidden[:, -1])
        bsz, seq = hidden.shape[0], hidden.shape[1]
        length = jnp.full((bsz,), seq, jnp.int32)
        if cfg.family in ("dense", "moe", "vlm"):
            ks, vs = parts  # (L, B, S, KH, hd)
            ks, vs = jax.vmap(lambda k, v: tf.prefill_cache_kv(cfg, k, v))(
                ks, vs)
            cache = tf.KVCache(k=grow(ks, seq), v=grow(vs, seq),
                               length=length)
        elif cfg.family == "ssm":
            cache = SSMCache(conv=parts.conv, state=parts.ssm, length=length)
        elif cfg.family == "hybrid":
            (ks, vs), (conv_g, st_g) = parts  # (G,B,S,KH,hd), (G,per,B,...)
            l = cfg.n_layers
            cache = HybridCache(
                conv=conv_g.reshape(l, *conv_g.shape[2:]),
                state=st_g.reshape(l, *st_g.shape[2:]),
                k=grow(ks, seq), v=grow(vs, seq), length=length)
        elif cfg.family == "encdec":
            (ks_vs, enc) = parts
            ks, vs = ks_vs
            xk = jnp.einsum(
                "bsd,ldh->lbsh", enc,
                params["layers"]["xattn"]["wk"]).reshape(
                    cfg.n_layers, enc.shape[0], enc.shape[1],
                    cfg.n_kv_heads, cfg.head_dim)
            xv = jnp.einsum(
                "bsd,ldh->lbsh", enc,
                params["layers"]["xattn"]["wv"]).reshape(
                    cfg.n_layers, enc.shape[0], enc.shape[1],
                    cfg.n_kv_heads, cfg.head_dim)
            cache = EncDecCache(k=grow(ks, seq), v=grow(vs, seq), xk=xk,
                                xv=xv, length=length)
        return logits, cache

    def decode_step(self, params, cache, tokens: Array):
        """One token for every sequence. tokens: (B, 1) int32."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, self.dt)
        cur = cache.length

        if cfg.family in ("dense", "moe", "vlm"):
            windows, thetas = tf.attention_pattern(cfg, cfg.n_layers)

            def body(h, xs):
                p_l, w_l, th_l, kc, vc = xs
                h, kc, vc = tf.block_decode(p_l, cfg, h, cur, w_l, th_l,
                                            kc, vc)
                return h, (kc, vc)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["layers"], windows, thetas, cache.k,
                          cache.v), unroll=cfg.scan_unroll)
            new_cache = tf.KVCache(k=ks, v=vs, length=cur + 1)
        elif cfg.family == "ssm":
            def body(h, xs):
                p_l, conv_l, st_l = xs
                y, st = ssm.mamba2_decode_step(
                    p_l["mamba"], layers.rmsnorm(p_l["ln"], h, cfg.rms_eps),
                    ssm.SSMState(conv=conv_l, ssm=st_l),
                    d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                    expand=cfg.ssm_expand)
                return h + y, (st.conv, st.ssm)

            x, (conv, st) = jax.lax.scan(
                body, x, (params["layers"], cache.conv, cache.state),
                unroll=cfg.scan_unroll)
            new_cache = SSMCache(conv=conv, state=st, length=cur + 1)
        elif cfg.family == "hybrid":
            per = cfg.shared_attn_every
            g = cfg.n_layers // per
            grouped = jax.tree.map(
                lambda a: a.reshape(g, per, *a.shape[1:]), params["layers"])
            conv_g = cache.conv.reshape(g, per, *cache.conv.shape[1:])
            st_g = cache.state.reshape(g, per, *cache.state.shape[1:])
            win = jnp.asarray(-1, jnp.int32)
            theta = jnp.asarray(cfg.rope_theta, jnp.float32)
            shared = params["shared"]

            def group(h, xs):
                p_g, conv_l, st_l, kc, vc = xs

                def inner(hh, ys):
                    p_l, c_l, s_l = ys
                    y, st = ssm.mamba2_decode_step(
                        p_l["mamba"],
                        layers.rmsnorm(p_l["ln"], hh, cfg.rms_eps),
                        ssm.SSMState(conv=c_l, ssm=s_l),
                        d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                        expand=cfg.ssm_expand)
                    return hh + y, (st.conv, st.ssm)

                h, (nc, ns) = jax.lax.scan(inner, h, (p_g, conv_l, st_l))
                h, kc, vc = tf.block_decode(shared, cfg, h, cur, win, theta,
                                            kc, vc)
                return h, (nc, ns, kc, vc)

            x, (conv, st, ks, vs) = jax.lax.scan(
                group, x, (grouped, conv_g, st_g, cache.k, cache.v),
                unroll=cfg.scan_unroll)
            new_cache = HybridCache(
                conv=conv.reshape(cfg.n_layers, *conv.shape[2:]),
                state=st.reshape(cfg.n_layers, *st.shape[2:]),
                k=ks, v=vs, length=cur + 1)
        elif cfg.family == "encdec":
            win = jnp.asarray(-1, jnp.int32)
            theta = jnp.asarray(cfg.rope_theta, jnp.float32)

            def body(h, xs):
                p_l, kc, vc, xk, xv = xs
                h, kc, vc = tf.block_decode(p_l, cfg, h, cur, win, theta,
                                            kc, vc, enc_kv=(xk, xv))
                return h, (kc, vc)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["layers"], cache.k, cache.v, cache.xk,
                          cache.xv), unroll=cfg.scan_unroll)
            new_cache = EncDecCache(k=ks, v=vs, xk=cache.xk, xv=cache.xv,
                                    length=cur + 1)
        hidden = layers.rmsnorm(params["final_norm"], x, cfg.rms_eps)
        return self._logits(params, hidden[:, 0]), new_cache

    # ------------------------------------------------------------------
    # Cache + input specs (for the dry-run and serving)
    # ------------------------------------------------------------------
    def init_cache_specs(self, batch: int, max_len: int):
        """ShapeDtypeStruct pytree of the decode cache."""
        cfg, dt = self.cfg, self.dt
        sds = jax.ShapeDtypeStruct
        ln = sds((batch,), jnp.int32)
        if cfg.family in ("dense", "moe", "vlm"):
            s_cache = max_len if cfg.sliding_window <= 0 \
                else min(max_len, cfg.sliding_window)
            kv = sds((cfg.n_layers, batch, s_cache, cfg.n_kv_heads,
                      cfg.head_dim), dt)
            return tf.KVCache(k=kv, v=kv, length=ln)
        if cfg.family == "ssm":
            return SSMCache(conv=self._conv_spec(batch),
                            state=self._state_spec(batch), length=ln)
        if cfg.family == "hybrid":
            g = cfg.n_layers // cfg.shared_attn_every
            kv = sds((g, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt)
            return HybridCache(conv=self._conv_spec(batch),
                               state=self._state_spec(batch),
                               k=kv, v=kv, length=ln)
        if cfg.family == "encdec":
            kv = sds((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                      cfg.head_dim), dt)
            xkv = sds((cfg.n_layers, batch, self._src_len(max_len),
                       cfg.n_kv_heads, cfg.head_dim), dt)
            return EncDecCache(k=kv, v=kv, xk=xkv, xv=xkv, length=ln)
        raise ValueError(cfg.family)

    def _conv_spec(self, batch):
        cfg = self.cfg
        d_inner = cfg.ssm_expand * cfg.d_model
        c = d_inner + 2 * cfg.ssm_state
        return jax.ShapeDtypeStruct((cfg.n_layers, batch, 3, c), self.dt)

    def _state_spec(self, batch):
        cfg = self.cfg
        d_inner = cfg.ssm_expand * cfg.d_model
        h = d_inner // cfg.ssm_head_dim
        return jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, h, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)

    @staticmethod
    def _src_len(tgt_len: int) -> int:
        return tgt_len  # encdec shapes: source frames match target length

    def make_input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """Batch ShapeDtypeStructs for a shape cell (no allocation)."""
        cfg, dt = self.cfg, self.dt
        sds = jax.ShapeDtypeStruct
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": sds((b, 1), jnp.int32)}
        batch: Dict[str, Any] = {}
        if cfg.family == "encdec":
            batch["src_embeds"] = sds((b, s, cfg.d_model), dt)
            batch["tokens"] = sds((b, s), jnp.int32)
        elif cfg.frontend in ("audio", "vision"):
            batch["embeds"] = sds((b, s, cfg.d_model), dt)
            if cfg.mrope_sections:
                batch["positions"] = sds((3, b, s), jnp.int32)
        else:
            batch["tokens"] = sds((b, s), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32)
        return batch


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
