from .model import build_model  # noqa: F401
