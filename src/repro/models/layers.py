"""Shared transformer layers: RMSNorm, RoPE (incl. M-RoPE sections),
grouped-query attention with optional QKV bias / sliding window / chunked
streaming-softmax (flash-style, pure JAX), SwiGLU MLP.

Everything is a pure function over explicit param pytrees (no flax offline);
init_* functions return the param trees. Compute dtype is the input dtype
(bf16 in production), accumulation fp32 where it matters.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * params["scale"]


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal sections)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4,
               sections: Tuple[int, ...] = ()) -> Array:
    """x: (B, S, H, hd). positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the rotary frequency channels are split into
    ``sections`` (t, h, w) groups; group g rotates by positions[g].
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        assert sections, "3-D positions need mrope sections"
        secs = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
        idx = jnp.arange(hd // 2)
        group = jnp.searchsorted(secs[1:], idx, side="right")  # (hd/2,)
        pos_g = positions[group]                   # (hd/2, B, S)
        ang = jnp.moveaxis(pos_g, 0, -1).astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(rng, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool, dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = d_model ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d_model, n_heads * head_dim), dtype) * s,
        "wk": jax.random.normal(k2, (d_model, n_kv_heads * head_dim), dtype) * s,
        "wv": jax.random.normal(k3, (d_model, n_kv_heads * head_dim), dtype) * s,
        "wo": jax.random.normal(k4, (n_heads * head_dim, d_model), dtype) * s,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def qkv_project(params, x: Array, n_heads: int, n_kv_heads: int,
                head_dim: int):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(b, s, n_heads, head_dim),
            k.reshape(b, s, n_kv_heads, head_dim),
            v.reshape(b, s, n_kv_heads, head_dim))


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Array | int = -1, q_offset: Array | int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    kv_len: Optional[Array] = None) -> Array:
    """Chunked streaming-softmax attention (flash-style algorithm in pure
    JAX/XLA — not a hand kernel; see DESIGN.md §6).

    q: (B, Sq, H, hd); k, v: (B, Skv, KH, hd) with H = KH * G (GQA).
    window: -1/0 => full; w > 0 => keys with qpos - kpos >= w are masked
    (sliding window). May be a traced scalar (per-layer pattern arrays).
    kv_len: optional (B,) valid KV length (decode/padded prefill).
    Memory: O(q_chunk * kv_chunk) scores per step instead of O(Sq * Skv).
    """
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = hd ** -0.5
    window = jnp.asarray(window)
    q_offset = jnp.asarray(q_offset)

    nq = -(-sq // q_chunk)
    pad_q = nq * q_chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    qp = qp.reshape(b, nq, q_chunk, kh, g, hd)

    nk = -(-skv // kv_chunk)
    pad_k = nk * kv_chunk - skv
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kp = k.reshape(b, nk, kv_chunk, kh, hd)
    vp = v.reshape(b, nk, kv_chunk, kh, hd)

    kpos_all = jnp.arange(nk * kv_chunk)
    valid_k = kpos_all < (skv if kv_len is None else kv_len[:, None])
    # (B?, nk*ck) -> (B, nk, ck)
    valid_k = jnp.broadcast_to(valid_k, (b, nk * kv_chunk)) \
        .reshape(b, nk, kv_chunk)

    def q_block(args):
        qi, iq = args  # (B, q_chunk, KH, G, hd), scalar index
        qpos = iq * q_chunk + jnp.arange(q_chunk) + q_offset  # (cq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, vkj, jk = inp  # (B, ck, KH, hd), ..., (B, ck), scalar
            kpos = jk * kv_chunk + jnp.arange(kv_chunk)
            s_ = jnp.einsum("bqkgd,bckd->bqkgc", qi, kj,
                            preferred_element_type=jnp.float32) * scale
            mask = vkj[:, None, None, None, :]
            if causal:
                cm = kpos[None, :] <= qpos[:, None]  # (cq, ck)
                wm = jnp.where(window > 0,
                               qpos[:, None] - kpos[None, :] < window, True)
                mask = mask & (cm & wm)[None, :, None, None, :]
            s_ = jnp.where(mask, s_, -1e30)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, q_chunk, kh, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kh, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kh, g, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0),
             jnp.moveaxis(valid_k, 1, 0), jnp.arange(nk)))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (jnp.moveaxis(qp, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cur_len: Array) -> Array:
    """Single-position attention against a (B, S_max, KH, hd) cache.

    q: (B, 1, H, hd). cur_len: (B,) number of valid cache entries (the new
    token's K/V must already be written). Plain einsum: scores are (B,H,S),
    tiny for one query.
    """
    b, _, h, hd = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd)
    s_ = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                    preferred_element_type=jnp.float32) * hd ** -0.5
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, :] < cur_len[:, None]  # (B, S)
    s_ = jnp.where(mask[:, None, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(rng, 3)
    s = d_model ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * (d_ff ** -0.5),
    }


def mlp(params, x: Array) -> Array:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) \
        @ params["w_down"]
