"""Backbone model zoo: decoder-only transformers (dense / MoE / pattern
attention), Mamba2 SSM stacks, Zamba2-style hybrids, and encoder-decoder.

All models are pure functions over stacked param pytrees; layer loops use
``lax.scan`` over stacked (L, ...) params so the HLO is O(1) in depth (vital
for the 80-cell dry-run on one CPU core). Per-layer heterogeneity (gemma3's
5:1 local:global pattern, theta switches) rides along the scan as traced
per-layer arrays rather than unrolled python branches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, moe, pspec, ssm
from .layers import apply_rope, decode_attention, flash_attention, mlp, \
    qkv_project, rmsnorm
from ..configs.base import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array        # (L, B, S_cache, KH, hd)
    v: Array
    length: Array   # (B,) tokens generated so far (absolute position)


def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int,
                  max_len: int, dtype=jnp.bfloat16) -> KVCache:
    s_cache = max_len if cfg.sliding_window <= 0 \
        else min(max_len, cfg.sliding_window)
    shape = (n_layers, batch, s_cache, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((batch,), jnp.int32))


def _cache_write(k_cache: Array, v_cache: Array, k_new: Array, v_new: Array,
                 pos: Array) -> Tuple[Array, Array]:
    """Write one position (B,1,KH,hd) at slot ``pos`` (B,) — rolling caches
    pass pos = cur_len % window."""
    b = k_new.shape[0]
    oh = jax.nn.one_hot(pos, k_cache.shape[1], dtype=k_cache.dtype)
    k_cache = k_cache * (1 - oh)[:, :, None, None] \
        + oh[:, :, None, None] * k_new
    v_cache = v_cache * (1 - oh)[:, :, None, None] \
        + oh[:, :, None, None] * v_new
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------

def init_block(rng, cfg: ModelConfig, dtype=jnp.bfloat16,
               cross_attn: bool = False) -> Dict[str, Any]:
    ks = jax.random.split(rng, 6)
    p = {
        "ln1": layers.init_rmsnorm(cfg.d_model, dtype),
        "attn": layers.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim,
                                      cfg.qkv_bias, dtype),
        "ln2": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.n_experts > 0:
        p["moe"] = moe.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dtype)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if cross_attn:
        p["ln_x"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["xattn"] = layers.init_attention(ks[2], cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim,
                                           False, dtype)
    return p


def block_forward(p, cfg: ModelConfig, x: Array, positions: Array,
                  window: Array, theta: Array, *, causal: bool = True,
                  enc_out: Optional[Array] = None, want_kv: bool = False):
    """Full-sequence block (train / prefill). Returns (x, aux, (k, v))."""
    x = pspec.constrain(x, "dp", None, None)
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    q, k, v = qkv_project(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim)
    q = apply_rope(q, positions, theta, cfg.mrope_sections)
    k = apply_rope(k, positions, theta, cfg.mrope_sections)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    x = x + o.reshape(x.shape[0], x.shape[1], -1) @ p["attn"]["wo"]
    x = pspec.constrain(x, "dp", None, None)

    if enc_out is not None:
        h = rmsnorm(p["ln_x"], x, cfg.rms_eps)
        qx = (h @ p["xattn"]["wq"]).reshape(
            x.shape[0], x.shape[1], cfg.n_heads, cfg.head_dim)
        kx = (enc_out @ p["xattn"]["wk"]).reshape(
            x.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        vx = (enc_out @ p["xattn"]["wv"]).reshape(
            x.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        ox = flash_attention(qx, kx, vx, causal=False, window=-1,
                             q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + ox.reshape(x.shape[0], x.shape[1], -1) @ p["xattn"]["wo"]

    h = rmsnorm(p["ln2"], x, cfg.rms_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts > 0:
        m, aux = moe.moe_ffn(p["moe"], h, cfg.n_experts_per_tok)
    else:
        m = mlp(p["mlp"], h)
    x = pspec.constrain(x + m, "dp", None, None)
    return x, aux, ((k, v) if want_kv else None)


def prefill_cache_kv(cfg: ModelConfig, k: Array, v: Array):
    """Turn full-sequence (B,S,KH,hd) K/V into the cache layout: the last
    ``window`` entries rolled so slot == pos % window (SWA), or unchanged."""
    w = cfg.sliding_window
    if w <= 0 or k.shape[1] <= w:
        return k, v
    s = k.shape[1]
    return (jnp.roll(k[:, -w:], s % w, axis=1),
            jnp.roll(v[:, -w:], s % w, axis=1))


def block_decode(p, cfg: ModelConfig, x: Array, cur_len: Array,
                 window: Array, theta: Array, k_cache: Array, v_cache: Array,
                 enc_kv: Optional[Tuple[Array, Array]] = None):
    """One-token block step against the cache. x: (B, 1, D).

    enc_kv: precomputed cross-attention (kx, vx) — (B, S_src, KH, hd);
    projecting the encoder output per decode step would cost a full
    S_src x d^2 GEMM per layer per token, so prefill does it once.
    """
    b = x.shape[0]
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    q, k, v = qkv_project(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim)
    pos = cur_len[:, None]  # (B,1) absolute positions
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)
    s_cache = k_cache.shape[1]
    slot = cur_len % s_cache if cfg.sliding_window > 0 else cur_len
    k_cache, v_cache = _cache_write(k_cache, v_cache, k, v, slot)
    eff_len = jnp.minimum(cur_len + 1, s_cache)
    o = decode_attention(q, k_cache, v_cache, eff_len)
    x = x + o.reshape(b, 1, -1) @ p["attn"]["wo"]

    if enc_kv is not None:
        kx, vx = enc_kv
        h = rmsnorm(p["ln_x"], x, cfg.rms_eps)
        qx = (h @ p["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        ox = decode_attention(qx, kx, vx,
                              jnp.full((b,), kx.shape[1], jnp.int32))
        x = x + ox.reshape(b, 1, -1) @ p["xattn"]["wo"]

    h = rmsnorm(p["ln2"], x, cfg.rms_eps)
    if cfg.n_experts > 0:
        m, _ = moe.moe_ffn(p["moe"], h, cfg.n_experts_per_tok)
    else:
        m = mlp(p["mlp"], h)
    return x + m, k_cache, v_cache


# ---------------------------------------------------------------------------
# Attention pattern arrays (per-layer window / theta, scanned with params)
# ---------------------------------------------------------------------------

def attention_pattern(cfg: ModelConfig, n_layers: int):
    """Returns (window (L,) i32, theta (L,) f32) as scan inputs."""
    windows = np.full(n_layers, -1, np.int32)
    thetas = np.full(n_layers, cfg.rope_theta, np.float32)
    if cfg.sliding_window > 0:
        windows[:] = cfg.sliding_window
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        for i in range(n_layers):
            if (i + 1) % (r + 1) == 0:
                windows[i] = -1                      # global layer
                thetas[i] = cfg.rope_theta_global
            else:
                windows[i] = cfg.local_window
                thetas[i] = cfg.rope_theta
    return jnp.asarray(windows), jnp.asarray(thetas)
