"""Activation sharding constraints with logical axis names.

``constrain(x, "dp", None, "model")`` resolves "dp" to ("pod","data") when
the ambient mesh has a pod axis, checks divisibility per dim, and no-ops
entirely when tracing without a mesh (CPU unit tests). These anchors
stop GSPMD from replicating the token dimension when weight shardings win
the propagation contest (observed: without the post-embedding anchor, every
per-layer GEMM ran on the full global batch per device).

The mesh probe itself goes through ``compat.get_abstract_mesh`` — the
JAX-version seam — never ``jax.sharding`` directly.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from . import compat

# experiment knob (§Perf A6/B2): resolve "dp" to include the model axis
# (pure-DP layouts that use every chip for batch parallelism)
DP_INCLUDE_MODEL = False


def _mesh():
    return compat.get_abstract_mesh()


def resolve_spec(spec, shape, names, sizes, *,
                 dp_include_model: bool = None):
    """Resolve logical axis names against mesh (names, sizes) per dim.

    Pure function of the spec, the array shape, and the mesh geometry —
    ``constrain`` feeds it the ambient mesh; tests feed it synthetic
    geometries. Any dim whose size is not divisible by the product of its
    mesh axes falls back to ``None`` (replicated) instead of an XLA error.
    """
    if dp_include_model is None:
        dp_include_model = DP_INCLUDE_MODEL
    sizes = dict(sizes)
    resolved = []
    for dim, s in enumerate(spec):
        if s == "dp":
            cand = ("pod", "data", "model") if dp_include_model \
                else ("pod", "data")
            axes = tuple(a for a in cand if a in names)
            n = 1
            for a in axes:
                n *= sizes[a]
            resolved.append(axes if axes and shape[dim] % n == 0 else None)
        elif s is None:
            resolved.append(None)
        else:
            ok = s in names and shape[dim] % sizes[s] == 0
            resolved.append(s if ok else None)
    return tuple(resolved)


def constrain(x, *spec):
    am = _mesh()
    if am is None:
        return x
    resolved = resolve_spec(spec, x.shape, tuple(am.axis_names),
                            zip(am.axis_names, am.axis_sizes))
    return jax.lax.with_sharding_constraint(x, P(*resolved))
