"""Activation sharding constraints with logical axis names.

``constrain(x, "dp", None, "model")`` resolves "dp" to ("pod","data") when
the ambient abstract mesh has a pod axis, checks divisibility per dim, and
no-ops entirely when tracing without a mesh (CPU unit tests). These anchors
stop GSPMD from replicating the token dimension when weight shardings win
the propagation contest (observed: without the post-embedding anchor, every
per-layer GEMM ran on the full global batch per device).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# experiment knob (§Perf A6/B2): resolve "dp" to include the model axis
# (pure-DP layouts that use every chip for batch parallelism)
DP_INCLUDE_MODEL = False


def _mesh():
    am = jax.sharding.get_abstract_mesh()
    if am is None or not am.axis_names:
        return None
    return am


def constrain(x, *spec):
    am = _mesh()
    if am is None:
        return x
    names = am.axis_names
    sizes = dict(zip(names, am.axis_sizes))
    resolved = []
    for dim, s in enumerate(spec):
        if s == "dp":
            cand = ("pod", "data", "model") if DP_INCLUDE_MODEL \
                else ("pod", "data")
            axes = tuple(a for a in cand if a in names)
            n = 1
            for a in axes:
                n *= sizes[a]
            resolved.append(axes if axes and x.shape[dim] % n == 0 else None)
        elif s is None:
            resolved.append(None)
        else:
            ok = s in names and x.shape[dim] % sizes[s] == 0
            resolved.append(s if ok else None)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
