"""JAX-version compat seam for the ambient-mesh probe.

``pspec.constrain`` needs to answer one question at trace time: *is there
an ambient mesh, and what are its axis names/sizes?* The public API for
that has drifted across JAX releases:

  * newer JAX (>= 0.5) exposes ``jax.sharding.get_abstract_mesh()``,
    populated by ``jax.sharding.use_mesh`` (and ``with mesh:`` blocks);
  * 0.4.x has no public probe — the ``with mesh:`` context lives on the
    thread-resources *physical* mesh
    (``jax._src.mesh.thread_resources.env.physical_mesh``);
  * with neither available, or with no mesh ambient, there is nothing to
    constrain against.

``get_abstract_mesh()`` here tries those in order and returns either a
mesh-like object exposing ``axis_names`` / ``axis_sizes`` (both the
AbstractMesh and the physical Mesh do) or ``None``. Callers keep the
contract pspec has always had: **no ambient mesh -> no-op**, bit-identical
to constraining on an empty spec.

``MESH_PROBE`` records which probe this process resolved to, so
``launch/runtime.py`` can surface a fallback in its env snapshot instead
of the next API drift silently killing the model zoo again (the
0.4.37 + ``get_abstract_mesh`` break took out 41 tests with one
AttributeError).
"""
from __future__ import annotations

from typing import Optional

import jax

# the public probe, when this JAX has one
_PUBLIC_PROBE = getattr(jax.sharding, "get_abstract_mesh", None)

# which probe path this process uses: "abstract" (public API) or
# "physical-fallback" (thread-resources mesh on older JAX)
MESH_PROBE = "abstract" if _PUBLIC_PROBE is not None else "physical-fallback"

# oldest JAX the fallback chain is known to cover (pinned in
# requirements-dev.txt; runtime.log() warns when the fallback is active)
JAX_FLOOR = "0.4.37"


def _physical_mesh():
    """Thread-resources physical mesh (``with mesh:`` on JAX 0.4.x)."""
    try:
        from jax._src import mesh as _mesh_lib
        return _mesh_lib.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        return None


def get_abstract_mesh(probe=None) -> Optional[object]:
    """The ambient mesh as an ``axis_names``/``axis_sizes`` carrier, or
    ``None`` when no mesh is ambient (or no probe exists in this JAX).

    ``probe`` overrides the public-API probe (tests monkeypatch it to
    lock in the fallback order).
    """
    probe = probe if probe is not None else _PUBLIC_PROBE
    if probe is not None:
        try:
            am = probe()
        except (AttributeError, TypeError):
            am = None
        # 0.4.x's private get_abstract_mesh returns () when unset; newer
        # versions return an empty AbstractMesh — both fail this guard
        if am is not None and getattr(am, "axis_names", None):
            return am
    pm = _physical_mesh()
    if pm is None or getattr(pm, "empty", True) or not pm.axis_names:
        return None
    return pm


def mesh_probe_status() -> dict:
    """Probe provenance for the runtime env snapshot."""
    am = get_abstract_mesh()
    return {
        "probe": MESH_PROBE,
        "jax_floor": JAX_FLOOR,
        "ambient_axes": tuple(am.axis_names) if am is not None else (),
    }
