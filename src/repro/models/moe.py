"""Top-k (Mixtral: top-2) mixture-of-experts FFN with capacity-based
scatter/gather dispatch.

Why not the classic GShard one-hot einsum dispatch: it materializes a
(T, E, C) tensor, i.e. O(T^2) at fixed capacity factor — at train_4k's
1M-token global batch that is exabytes. The scatter formulation below is
O(T*k*d): tokens are placed into an (E*C, d) buffer by computed slot ids
(position-within-expert via one cumsum over (T*k, E)), expert FFNs run as
an E-batched GEMM, and outputs gather back by the same slot ids. Overflow
beyond capacity goes to a trash slot (standard token dropping).

Weight layouts (DESIGN.md §5): "tp" shards each expert's FFN hidden dim
over `model` (default); "ep" (experts over a mesh axis) is exercised in the
§Perf hillclimb with a reshaped mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def init_moe(rng, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = d_model ** -0.5
    return {
        "router": jax.random.normal(k1, (d_model, n_experts), jnp.float32) * s,
        "w_gate": jax.random.normal(k2, (n_experts, d_model, d_ff), dtype) * s,
        "w_up": jax.random.normal(k3, (n_experts, d_model, d_ff), dtype) * s,
        "w_down": jax.random.normal(k4, (n_experts, d_ff, d_model), dtype)
        * (d_ff ** -0.5),
    }


def moe_ffn(params, x: Array, n_experts_per_tok: int = 2,
            capacity_factor: float = 1.25):
    """x: (B, S, D) -> (out (B, S, D), aux load-balancing loss)."""
    b, s, d = x.shape
    e = params["w_gate"].shape[0]
    k = n_experts_per_tok
    t = b * s
    xt = x.reshape(t, d)
    logits = xt.astype(jnp.float32) @ params["router"]     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                   # (T, k)
    topv = (topv / topv.sum(axis=-1, keepdims=True)).astype(x.dtype)

    cap = max(int(capacity_factor * t * k / e), 8)

    # position of each (token, slot) within its expert, FCFS by token index
    flat_e = topi.reshape(t * k)                           # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=-1) - 1
    keep = pos < cap
    # overflow -> out-of-bounds slot: scatter drops OOB under jit, gather
    # back-fills zeros; keeps the buffer exactly (E*C, D) so the expert dim
    # can shard over an `expert` mesh axis (EP layout, §Perf)
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)

    xrep = jnp.repeat(xt, k, axis=0)                       # (T*k, D)
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xrep, mode="drop")
    xin = buf.reshape(e, cap, d)

    hmid = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, params["w_gate"],
                                   preferred_element_type=jnp.float32))
            * jnp.einsum("ecd,edf->ecf", xin, params["w_up"],
                         preferred_element_type=jnp.float32)).astype(x.dtype)
    xout = jnp.einsum("ecf,efd->ecd", hmid, params["w_down"],
                      preferred_element_type=jnp.float32).astype(x.dtype)

    # gather back (OOB -> zeros) and combine with renormalized weights
    back = jnp.take(xout.reshape(e * cap, d), slot, axis=0,
                    mode="fill", fill_value=0).reshape(t, k, d)
    out = (back * topv[..., None]).sum(axis=1)

    # Switch-style load-balance aux: E * sum_e f_e * p_e
    frac = onehot.reshape(t, k, e).sum(axis=1).astype(jnp.float32).mean(axis=0)
    pmean = probs.mean(axis=0)
    aux = e * jnp.sum(frac * pmean)
    return out.reshape(b, s, d), aux
