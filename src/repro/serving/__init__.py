"""Survival inference serving subsystem — from fitted beta to risk fleet.

Module map
----------
``artifacts.py``
    ``SurvivalModel`` — the deployable artifact: dense or k-sparse beta,
    Breslow/Efron cumulative baseline hazard on a fixed time grid (one row
    per stratum), built in JAX from training data via the same O(n)
    suffix-scan machinery as the solvers (``fit_survival_model``), and
    persisted with train/checkpoint.py's npy-per-leaf + atomic-rename
    idiom (``save`` / ``load``). The manifest carries a sha256 per leaf;
    ``load`` verifies them, so a truncated or bit-flipped ``.npy`` raises
    ``ArtifactCorrupt`` instead of scoring garbage.

``engine.py``
    ``ScoringEngine`` — jit-compiled batched scoring: risk scores,
    survival curves ``S(t|x) = exp(-H0(t) e^{x beta})`` over the grid
    (fused Pallas kernel ``kernels/survival_curves.py`` on the
    unstratified path), and median-survival queries. k-sparse models
    gather only support columns (O(k) per request). Batches pad to
    power-of-two buckets so the jit cache stays logarithmic;
    ``prewarm()`` compiles a bucket set ahead of going live.

``service.py``
    ``RiskService`` — continuous micro-batching with fleet-grade
    admission control: two priority classes (``Priority.HIGH`` /
    ``Priority.LOW``) with strict-priority dequeue and shed-low-first
    eviction at a bounded queue, server-side per-request deadlines
    (expired work dropped at batch-form time with
    ``error="deadline_exceeded"`` responses, never a wasted jit
    dispatch), a condition-signaled ``wait()`` (no busy-poll), and a
    crash-safe drain loop — engine exceptions become per-request error
    responses plus a ``SERVING``/``DEGRADED``/``DOWN`` readiness
    transition (``health()``), with bounded exponential-backoff retry
    for transients. Uncollected responses are evicted (timeout abandon +
    TTL sweep) so a long-running service stays bounded.

``registry.py``
    ``ModelRegistry`` — named model fleet over one service slot:
    ``load`` (checksum-verified) -> background ``prewarm`` -> atomic
    ``swap`` (generation-counted, zero dropped requests) -> ``unload``.
    ``rollout()`` chains them for one-call, zero-downtime model updates
    under live traffic.

``chaos.py``
    Deterministic fault injection — ``ChaosEngine`` (seeded/scheduled
    engine exceptions + latency spikes), ``corrupt_artifact`` (truncate /
    bit-flip a leaf), ``flood`` (concurrent queue pressure) — the
    injectors the robustness tests and the overload benchmark drive to
    prove every failure mode degrades gracefully.

End-to-end wiring: ``examples/serve_risk_api.py`` (beam-search model ->
artifact -> registry -> service, with a live hot-swap);
throughput/latency numbers: ``benchmarks/bench_serving.py``; open-loop
overload + hot-swap-under-load benchmark: ``benchmarks/bench_overload.py``
(committed as ``BENCH_9.json``, gated by ``run.py --smoke``); roofline
cost models for the scoring kernels: ``analysis/roofline.py``
(SERVING_KERNELS).
"""
from .artifacts import (ArtifactCorrupt, SurvivalModel,  # noqa: F401
                        fit_survival_model)
from .chaos import ChaosEngine, EngineFault, corrupt_artifact  # noqa: F401
from .engine import ScoringEngine  # noqa: F401
from .registry import ModelEntry, ModelRegistry  # noqa: F401
from .service import (HEALTH_STATES, Priority, QueueFull,  # noqa: F401
                      RiskService, ScoreRequest, ScoreResponse,
                      ScoreTimeout)
