"""Survival inference serving subsystem — from fitted beta to risk API.

Module map
----------
``artifacts.py``
    ``SurvivalModel`` — the deployable artifact: dense or k-sparse beta,
    Breslow/Efron cumulative baseline hazard on a fixed time grid (one row
    per stratum), built in JAX from training data via the same O(n)
    suffix-scan machinery as the solvers (``fit_survival_model``), and
    persisted with train/checkpoint.py's npy-per-leaf + atomic-rename
    idiom (``save`` / ``load``).

``engine.py``
    ``ScoringEngine`` — jit-compiled batched scoring: risk scores,
    survival curves ``S(t|x) = exp(-H0(t) e^{x beta})`` over the grid
    (fused Pallas kernel ``kernels/survival_curves.py`` on the
    unstratified path), and median-survival queries. k-sparse models
    gather only support columns (O(k) per request). Batches pad to
    power-of-two buckets so the jit cache stays logarithmic.

``service.py``
    ``RiskService`` — continuous micro-batching request queue mirroring
    launch/serve.py's loop: submit -> queue -> micro-batch -> jit score ->
    respond, with req/s and p50/p99 latency instrumentation, per-batch
    tracing spans + always-on metrics (``repro.obs``), a bounded-queue
    shedding mode (``QueueFull``), and explicit ``ScoreTimeout`` waits.

End-to-end wiring: ``examples/serve_risk_api.py`` (beam-search model ->
artifact -> service); throughput/latency numbers:
``benchmarks/bench_serving.py``; roofline cost models for the scoring
kernels: ``analysis/roofline.py`` (SERVING_KERNELS).
"""
from .artifacts import SurvivalModel, fit_survival_model  # noqa: F401
from .engine import ScoringEngine  # noqa: F401
from .service import (QueueFull, RiskService, ScoreRequest,  # noqa: F401
                      ScoreResponse, ScoreTimeout)
