"""Model registry with background pre-warm and atomic hot-swap.

The fleet-management layer over ``RiskService``: where the service owns
*requests*, the registry owns *models*. It keeps a table of named
``SurvivalModel`` artifacts, each wrapped in its own ``ScoringEngine``,
and rolls a freshly trained model into the live serving slot with zero
dropped requests:

    reg = ModelRegistry(service)
    reg.load("champ_v2", "/models/champ_v2")     # verify + build + warm
    reg.swap("champ_v2")                          # atomic, between batches
    reg.unload("champ_v1")                        # drop the old engine

Lifecycle of an entry: ``loading`` (artifact read + checksum verify —
a corrupt artifact fails here with ``ArtifactCorrupt``, never reaching
the live slot) -> ``warming`` (the engine's jit buckets compile in the
background while the old model keeps serving) -> ``ready`` (swappable)
-> ``live`` after ``swap`` -> ``unloaded`` once retired. A failure at
any stage parks the entry at ``failed`` with the error recorded; the
live engine is untouched.

``swap`` bumps a monotone ``generation`` counter (stamped on the entry
it promoted) and calls ``RiskService.set_engine``, which replaces the
engine slot under the service lock *between* micro-batches: the
in-flight batch finishes on the engine it snapshotted, queued requests
score on the new one — the saxml servable-model rollout discipline
(load/warm off-path, serve continuously).

``load(..., block=False)`` warms on a daemon thread for rollouts under
live traffic; ``rollout()`` is the one-call convenience (load -> swap ->
unload previous). Metrics: ``registry_models`` gauge,
``registry_swaps_total`` / ``registry_load_failures_total`` counters,
plus ``registry.*`` lifecycle events on the JSONL sink.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Sequence, Union

from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from .artifacts import ArtifactCorrupt, SurvivalModel
from .engine import ScoringEngine
from .service import RiskService

# entry lifecycle states
LOADING, WARMING, READY, LIVE, FAILED, UNLOADED = (
    "loading", "warming", "ready", "live", "failed", "unloaded")


@dataclasses.dataclass
class ModelEntry:
    """One registered model and its serving state."""

    model_id: str
    state: str = LOADING
    path: Optional[str] = None
    model: Optional[SurvivalModel] = None
    engine: Optional[ScoringEngine] = None
    error: Optional[str] = None
    generation: Optional[int] = None     # generation at which it went live
    compiles: int = 0                    # jit compilations during warm

    @property
    def ready(self) -> bool:
        return self.state in (READY, LIVE)


class ModelRegistry:
    """Named ``SurvivalModel`` fleet feeding one ``RiskService`` slot."""

    def __init__(self, service: Optional[RiskService] = None, *,
                 engine_factory: Optional[
                     Callable[[SurvivalModel], ScoringEngine]] = None,
                 prewarm_batches: Optional[Sequence[int]] = None,
                 prewarm: bool = True,
                 registry: Optional[obs_metrics.Registry] = None):
        self._service = service
        self._factory = engine_factory or ScoringEngine
        if prewarm_batches is None:
            # every pow-2 bucket the service can hit: a partially-warmed
            # engine stalls live traffic on mid-ladder compiles (a batch
            # of 3 hits bucket 4) — warm the whole ladder by default
            mb = max(service.max_batch if service is not None else 64, 1)
            prewarm_batches = tuple(
                1 << i for i in range((mb - 1).bit_length() + 1))
        self.prewarm_batches = tuple(int(b) for b in prewarm_batches)
        self.prewarm = bool(prewarm)
        self._lock = threading.Lock()
        self._entries: Dict[str, ModelEntry] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self.generation = 0
        self.live_id: Optional[str] = None
        reg = registry if registry is not None else obs_metrics.REGISTRY
        self._m_models = reg.gauge(
            "registry_models", "models registered (any state)")
        self._m_models.set_fn(lambda: len(self._entries))
        self._m_swaps = reg.counter(
            "registry_swaps_total", "live-engine model swaps")
        self._m_failures = reg.counter(
            "registry_load_failures_total",
            "model loads that failed (corrupt artifact, bad build)")

    # -- load / warm -------------------------------------------------------

    def _build(self, entry: ModelEntry,
               source: Union[str, SurvivalModel]) -> None:
        """Artifact read (checksum-verified) -> engine -> warm buckets.
        Any failure parks the entry at FAILED; nothing touches the live
        slot until an explicit ``swap``."""
        try:
            if isinstance(source, SurvivalModel):
                model = source
            else:
                entry.path = str(source)
                model = SurvivalModel.load(entry.path)   # verifies sha256
            engine = self._factory(model)
            with self._lock:
                entry.model = model
                entry.engine = engine
                entry.state = WARMING
            if self.prewarm:
                kinds = ("score_curves" if self._service is not None
                         and self._service.return_curves else "score",)
                entry.compiles = engine.prewarm(
                    self.prewarm_batches, kinds=kinds,
                    strata=model.n_strata > 1)
            with self._lock:
                entry.state = READY
            obs_events.emit("registry.ready", model_id=entry.model_id,
                            compiles=entry.compiles)
        except Exception as e:
            with self._lock:
                entry.state = FAILED
                entry.error = f"{type(e).__name__}: {e}"
            self._m_failures.inc()
            obs_events.emit("registry.load_failed",
                            model_id=entry.model_id, error=entry.error)

    def load(self, model_id: str, source: Union[str, SurvivalModel], *,
             block: bool = True) -> ModelEntry:
        """Register ``model_id`` from an artifact path or an in-memory
        ``SurvivalModel`` and warm its engine. ``block=False`` warms on a
        daemon thread (rollouts under live traffic); poll
        ``entry.state`` or call ``wait_ready``. Re-loading an id replaces
        its entry unless that id is currently live."""
        with self._lock:
            if model_id == self.live_id:
                raise ValueError(
                    f"model {model_id!r} is live; load under a new id "
                    "and swap")
            entry = ModelEntry(model_id=model_id)
            self._entries[model_id] = entry
        obs_events.emit("registry.load", model_id=model_id,
                        source=source if isinstance(source, str) else
                        "<in-memory>")
        if block:
            self._build(entry, source)
            if entry.state == FAILED:
                exc = (ArtifactCorrupt
                       if "ArtifactCorrupt" in (entry.error or "")
                       else RuntimeError)
                raise exc(f"load of {model_id!r} failed: {entry.error}")
        else:
            t = threading.Thread(target=self._build,
                                 args=(entry, source), daemon=True,
                                 name=f"registry-warm-{model_id}")
            self._threads[model_id] = t
            t.start()
        return entry

    def wait_ready(self, model_id: str, timeout: float = 60.0) -> ModelEntry:
        """Join a background load; raises on timeout or failed load."""
        t = self._threads.pop(model_id, None)
        if t is not None:
            t.join(timeout)
            if t.is_alive():
                self._threads[model_id] = t
                raise TimeoutError(
                    f"model {model_id!r} still warming after {timeout}s")
        entry = self.get(model_id)
        if entry.state == FAILED:
            raise RuntimeError(
                f"load of {model_id!r} failed: {entry.error}")
        return entry

    # -- swap / unload -----------------------------------------------------

    def swap(self, model_id: str) -> int:
        """Promote a READY model into the live engine slot. Atomic with
        respect to the serving loop (between micro-batches); zero queued
        or in-flight requests are dropped. Returns the new generation."""
        with self._lock:
            entry = self._entries.get(model_id)
            if entry is None:
                raise KeyError(f"unknown model {model_id!r}")
            if not entry.ready or entry.engine is None:
                raise RuntimeError(
                    f"model {model_id!r} not swappable (state="
                    f"{entry.state}{', ' + entry.error if entry.error else ''})")
            self.generation += 1
            gen = entry.generation = self.generation
            prev_id, self.live_id = self.live_id, model_id
            entry.state = LIVE
            prev = self._entries.get(prev_id) if prev_id else None
            if prev is not None and prev.state == LIVE:
                prev.state = READY
            engine = entry.engine
        if self._service is not None:
            self._service.set_engine(engine)
        self._m_swaps.inc()
        obs_events.emit("registry.swap", model_id=model_id,
                        generation=gen, previous=prev_id)
        return gen

    def unload(self, model_id: str) -> None:
        """Retire a model: drop its engine (jit cache) and artifact
        references. The live model cannot be unloaded — swap first."""
        with self._lock:
            if model_id == self.live_id:
                raise ValueError(
                    f"model {model_id!r} is live; swap before unloading")
            entry = self._entries.get(model_id)
            if entry is None:
                raise KeyError(f"unknown model {model_id!r}")
            entry.engine = None
            entry.model = None
            entry.state = UNLOADED
        self._threads.pop(model_id, None)
        obs_events.emit("registry.unload", model_id=model_id)

    def rollout(self, model_id: str, source: Union[str, SurvivalModel],
                *, unload_previous: bool = True) -> int:
        """Load + warm + swap in one call; optionally unloads the model
        it replaced. The load/warm happens entirely off the serving path,
        so live traffic only ever sees warmed engines."""
        self.load(model_id, source, block=True)
        prev = self.live_id
        gen = self.swap(model_id)
        if unload_previous and prev is not None and prev != model_id:
            self.unload(prev)
        return gen

    # -- introspection -----------------------------------------------------

    def get(self, model_id: str) -> ModelEntry:
        with self._lock:
            entry = self._entries.get(model_id)
        if entry is None:
            raise KeyError(f"unknown model {model_id!r}")
        return entry

    def engine(self, model_id: Optional[str] = None) -> ScoringEngine:
        """The live engine (default) or a named entry's engine."""
        with self._lock:
            mid = model_id or self.live_id
            entry = self._entries.get(mid) if mid else None
        if entry is None or entry.engine is None:
            raise KeyError(f"no engine for model {mid!r}")
        return entry.engine

    def status(self) -> dict:
        """Readiness surface: live id, generation, per-model states."""
        with self._lock:
            return {
                "live": self.live_id,
                "generation": self.generation,
                "models": {mid: {"state": e.state, "error": e.error,
                                 "generation": e.generation}
                           for mid, e in self._entries.items()},
            }
