"""jit-compiled batched scoring engine over a SurvivalModel artifact.

Three query types, all O(batch) jit calls over device-resident model state:

  * ``risk_scores``      exp(x beta)                       -> (b,)
  * ``survival_curves``  exp(-H0_s(t) exp(x beta))         -> (b, g)
  * ``median_survival``  first grid time with S(t|x) <= .5 -> (b,)

Sparse fast path: a beam-search model with support size k gathers only the
k support columns on the host (O(b k) transferred instead of O(b p)) and
scores with the gathered ``beta_support`` — per-request work is O(k), the
serving-side payoff of FastSurvival's cardinality-constrained models.

Shape bucketing: incoming batches are zero-padded up to the next power of
two, so the jit cache holds at most log2(max_batch) entries per query type
instead of one compilation per distinct batch size. Cache misses (i.e.
fresh compilations) are counted for the instrumentation in service.py.

The unstratified curve evaluation runs through the fused Pallas kernel
(kernels/survival_curves.py); the stratified path routes through the
scalar-prefetch variant (per-request baseline row selected by the kernel's
index map) on TPU and falls back to a jnp gather elsewhere, where Pallas
only interprets.

Data-parallel scoring: ``shard=k`` (or ``"auto"``) wraps every bucketed
query body in ``shard_map`` over a 1-D ``data`` mesh from
``launch/mesh.py`` — rows split over shards, model state replicated — and
bucketing becomes per-shard (bucket = shards * next_pow2(ceil(b /
shards))), so each shard sees a power-of-two block. ``shard=None`` (the
default) is the legacy single-device path, bit-identical to previous
behavior.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels import ops
from ..launch import mesh as launch_mesh
from ..launch import runtime as launch_runtime
from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from ..obs import trace
from .artifacts import SurvivalModel

_ETA_CLIP = 30.0

# shared across engines: compile blowups (a bucketing regression) show up
# as a climbing counter, bucket skew as a lopsided histogram
_M_COMPILES = obs_metrics.REGISTRY.counter(
    "engine_jit_compiles_total", "fresh jit-cache compilations",
    ("kind",))
_M_CALLS = obs_metrics.REGISTRY.counter(
    "engine_calls_total", "scoring calls", ("kind",))
_M_BUCKET = obs_metrics.REGISTRY.histogram(
    "engine_bucket_size", "padded power-of-two batch buckets hit",
    buckets=obs_metrics.POW2_BUCKETS)


def _next_pow2(b: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(b, 1)))), 0)


class ScoringEngine:
    """Batched scorer with a shape-bucketed jit cache."""

    def __init__(self, model: SurvivalModel, *, use_sparse: Optional[bool]
                 = None, max_sparse_k: int = 64, use_kernel: bool = True,
                 shard: Union[int, str, None] = None,
                 use_strat_kernel: Optional[bool] = None):
        self.model = model
        if use_sparse is None:
            use_sparse = (model.is_sparse
                          and model.k is not None and model.k <= max_sparse_k)
        self.use_sparse = bool(use_sparse and model.is_sparse)
        self.use_kernel = use_kernel
        # stratified scalar-prefetch kernel: native on TPU; elsewhere the
        # interpreted Pallas call loses to the jnp gather, so default off
        if use_strat_kernel is None:
            use_strat_kernel = jax.default_backend() == "tpu"
        self.use_strat_kernel = bool(use_strat_kernel and use_kernel)
        # shard=None -> legacy single-device path (bit-identical: no mesh,
        # no shard_map in the trace); "auto" -> $REPRO_DATA_SHARDS or one
        # shard per local device; int -> explicit, clamped to devices
        if shard is None:
            self.shard = 1
        elif shard == "auto":
            self.shard = (launch_runtime.data_shards()
                          or jax.local_device_count())
        else:
            self.shard = int(shard)
        self.shard = max(1, min(self.shard, jax.local_device_count()))
        self._mesh = (launch_mesh.make_data_mesh(self.shard)
                      if self.shard > 1 else None)
        self._support = (np.asarray(model.support)
                         if model.support is not None else None)
        beta = (model.beta_support if self.use_sparse else model.beta)
        self._beta = jnp.asarray(np.asarray(beta, np.float32))
        self._h0 = jnp.asarray(np.asarray(model.base_cumhaz, np.float32))
        self._grid = jnp.asarray(np.asarray(model.time_grid, np.float32))
        self._cache: dict = {}
        self.compiles = 0
        self.calls = 0

    # -- feature handling --------------------------------------------------

    @property
    def feature_dim(self) -> int:
        """Columns the jit'd matvec consumes (k on the sparse path)."""
        return (len(self._support) if self.use_sparse
                else self.model.p)

    def _gather(self, x: np.ndarray) -> np.ndarray:
        """Host-side support gather: accepts (b, p) full features or
        (b, k) pre-gathered ones on the sparse path."""
        x = np.atleast_2d(np.asarray(x, np.float32))
        if self.use_sparse and x.shape[1] == self.model.p:
            x = x[:, self._support]
        if x.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected {self.feature_dim} or {self.model.p} features, "
                f"got {x.shape[1]}")
        return x

    def _pad(self, x: np.ndarray):
        b = x.shape[0]
        if self.shard > 1:
            # per-shard pow-2 bucketing: every shard sees a power-of-two
            # block, the jit cache stays log-sized per shard count
            bucket = self.shard * _next_pow2(-(-b // self.shard))
        else:
            bucket = _next_pow2(b)
        if bucket != b:
            x = np.pad(x, ((0, bucket - b), (0, 0)))
        return x, b, bucket

    def _fn(self, kind: str, bucket: int):
        key = (kind, bucket, self.feature_dim)
        fn = self._cache.get(key)
        if fn is None:
            self.compiles += 1
            _M_COMPILES.inc(kind=kind)
            obs_events.emit("engine.compile", query=kind, bucket=bucket,
                            feature_dim=self.feature_dim,
                            cache_entries=len(self._cache))
            fn = self._build(kind)
            self._cache[key] = fn
        return fn

    # -- jit'd query bodies ------------------------------------------------

    def _build(self, kind: str):
        h0 = self._h0
        grid = self._grid
        use_kernel = self.use_kernel and h0.shape[0] == 1
        use_strat = self.use_strat_kernel and h0.shape[0] > 1

        def eta_of(xb, beta):
            return jnp.clip(xb @ beta, -_ETA_CLIP, _ETA_CLIP)

        def curves(xb, beta, strata):
            if use_kernel:
                return ops.survival_curves(xb @ beta, h0[0])
            if use_strat:
                # baseline-row gather folded into the kernel's index map
                return ops.survival_curves_stratified(xb @ beta, h0, strata)
            if h0.shape[0] == 1:
                # single stratum: broadcast the one baseline row instead of
                # materializing a (b, g) gather panel
                hh = h0[0][None, :]
            else:
                hh = h0[strata]                  # (b, g) baseline gather
            return jnp.exp(-hh * jnp.exp(eta_of(xb, beta))[:, None])

        def median_of(s):
            below = s <= 0.5
            hit = jnp.any(below, axis=1)
            idx = jnp.argmax(below, axis=1)
            return jnp.where(hit, grid[idx], jnp.inf)

        if kind == "risk":
            def fn(xb, beta, strata):
                return jnp.exp(eta_of(xb, beta))
        elif kind == "curves":
            fn = curves
        elif kind == "median":
            def fn(xb, beta, strata):
                return median_of(curves(xb, beta, strata))
        elif kind in ("score", "score_curves"):
            # fused service query: one transfer + one curve panel per batch
            def fn(xb, beta, strata):
                s = curves(xb, beta, strata)
                out = (jnp.exp(eta_of(xb, beta)), median_of(s))
                return out + ((s,) if kind == "score_curves" else ())
        else:
            raise ValueError(kind)
        if self._mesh is not None:
            fn = self._shard_wrap(fn, kind)
        return jax.jit(fn)

    _OUT_SPECS = {
        "risk": P("data"),
        "curves": P("data", None),
        "median": P("data"),
        "score": (P("data"), P("data")),
        "score_curves": (P("data"), P("data"), P("data", None)),
    }

    def _shard_wrap(self, fn, kind: str):
        """Rows split over the ``data`` mesh, model state replicated.

        The bucketed batch is divisible by the shard count by
        construction (see ``_pad``), so every shard runs the same
        pow-2-shaped pure body; outputs concatenate along rows."""
        return launch_mesh.shard_map_compat(
            fn, mesh=self._mesh,
            in_specs=(P("data"), P(), P("data")),
            out_specs=self._OUT_SPECS[kind])

    def _run(self, kind: str, x, strata):
        with trace.span("engine.score", kind=kind) as sp_span:
            xh = self._gather(x)
            xp, b, bucket = self._pad(xh)
            sp = np.zeros(bucket, np.int32)
            if strata is not None:
                s = np.asarray(strata, np.int32)
                if s.size and (s.min() < 0 or s.max() >= self.model.n_strata):
                    # the jit'd gather would silently clamp out-of-range rows
                    raise ValueError(
                        f"stratum indices must be in [0, {self.model.n_strata})"
                        f", got range [{s.min()}, {s.max()}]")
                sp[:b] = s
            self.calls += 1
            _M_CALLS.inc(kind=kind)
            _M_BUCKET.observe(bucket)
            sp_span.set(b=b, bucket=bucket)
            if self._mesh is not None:
                # leave host arrays uncommitted: jnp.asarray would pin
                # them to device 0 and force a reshard copy on every call
                out = self._fn(kind, bucket)(xp, self._beta, sp)
            else:
                out = self._fn(kind, bucket)(jnp.asarray(xp), self._beta,
                                             jnp.asarray(sp))
            if isinstance(out, tuple):
                return tuple(np.asarray(o)[:b] for o in out)
            return np.asarray(out)[:b]

    # -- public API --------------------------------------------------------

    def risk_scores(self, x: np.ndarray) -> np.ndarray:
        """exp(x beta) for a (b, p) or pre-gathered (b, k) batch."""
        return self._run("risk", x, None)

    def survival_curves(self, x: np.ndarray,
                        strata: Optional[np.ndarray] = None) -> np.ndarray:
        """(b, g) S(t|x) on the model grid. ``strata`` are baseline row
        indices (positions in model.strata_labels), default stratum 0."""
        return self._run("curves", x, strata)

    def median_survival(self, x: np.ndarray,
                        strata: Optional[np.ndarray] = None) -> np.ndarray:
        """First grid time where S(t|x) drops to 1/2 (inf if never)."""
        return self._run("median", x, strata)

    def score(self, x: np.ndarray, strata: Optional[np.ndarray] = None,
              with_curves: bool = False):
        """Fused service query: (risk, median[, curves]) from a single jit
        call — one host->device transfer and one curve panel per batch."""
        return self._run("score_curves" if with_curves else "score",
                         x, strata)

    def prewarm(self, batch_sizes=(1, 64), kinds=("score",),
                strata: bool = False) -> int:
        """Compile (and execute once, on zeros) the jit buckets a service
        will hit, so the first live request after a hot-swap never pays a
        trace+compile. ``batch_sizes`` are rounded up to their pow-2
        buckets; duplicate buckets compile once. Returns the number of
        fresh compilations. Safe to call from a background thread — the
        registry pre-warms new models off the serving path."""
        before = self.compiles
        seen = set()
        for b in batch_sizes:
            _, _, bucket = self._pad(np.zeros((int(b), 1), np.float32))
            if bucket in seen:
                continue
            seen.add(bucket)
            x = np.zeros((bucket, self.feature_dim), np.float32)
            s = (np.zeros(bucket, np.int32)
                 if strata and self.model.n_strata > 1 else None)
            for kind in kinds:
                self._run(kind, x, s)
        return self.compiles - before

    def cache_info(self) -> dict:
        return {"entries": len(self._cache), "compiles": self.compiles,
                "calls": self.calls, "shard": self.shard}
