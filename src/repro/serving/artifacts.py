"""SurvivalModel serving artifact: coefficients + baseline cumulative hazard.

Turns a fitted CPH ``beta`` (dense CD, L1 path, or beam-search k-sparse)
into everything a scoring engine needs at request time:

  * ``beta`` (p,) plus, when the model is sparse, the support indices and
    the gathered ``beta_support`` (k,) for the O(k) fast path;
  * the Breslow (or Efron) cumulative baseline hazard evaluated on a fixed
    ``time_grid`` (g,), stored per stratum as ``base_cumhaz`` (n_strata, g)
    so ``S(t|x, s) = exp(-H0_s(t) * exp(x beta))`` is a gather + exp.

The baseline is computed in JAX with the same O(n) suffix-scan machinery
as training (``cox.revcumsum`` / ``risk_stats``): with w = exp(eta - m) and
S0 at each sample's Breslow risk_start, the per-sample cumulative hazard is
``cumsum(delta / S0) * exp(-m)`` — the ``a`` statistic of Theorem 3.1
rescaled by the stabilizer. Efron replaces S0 by the tie-corrected
``S0 - (j/d) W_d`` within each tie group.

Persistence follows train/checkpoint.py's idiom: one .npy per array field
plus a manifest.json, written to a tmp dir that is atomically renamed, so
a crash mid-save can never corrupt a served artifact. The manifest
carries a sha256 per array leaf (format 2); ``load`` verifies them so a
truncated or bit-flipped ``.npy`` raises ``ArtifactCorrupt`` instead of
scoring garbage. Format-1 manifests (no checksums) still load.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core import cox

_ARRAY_FIELDS = ("beta", "time_grid", "base_cumhaz", "support",
                 "beta_support", "strata_labels")
_MANIFEST = "manifest.json"


class ArtifactCorrupt(RuntimeError):
    """A persisted SurvivalModel failed integrity checks on load."""


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class SurvivalModel:
    """Host-side serving artifact (numpy; the engine device-puts it)."""

    beta: np.ndarray                       # (p,) dense coefficients
    time_grid: np.ndarray                  # (g,) fixed evaluation grid
    base_cumhaz: np.ndarray                # (n_strata, g) H0 per stratum
    ties: str = "breslow"                  # "breslow" | "efron"
    support: Optional[np.ndarray] = None   # (k,) int32 nonzero indices
    beta_support: Optional[np.ndarray] = None  # (k,) gathered coefficients
    strata_labels: Optional[np.ndarray] = None  # (n_strata,) original labels

    @property
    def p(self) -> int:
        return self.beta.shape[0]

    @property
    def n_grid(self) -> int:
        return self.time_grid.shape[0]

    @property
    def n_strata(self) -> int:
        return self.base_cumhaz.shape[0]

    @property
    def k(self) -> Optional[int]:
        return None if self.support is None else int(self.support.shape[0])

    @property
    def is_sparse(self) -> bool:
        return self.support is not None

    # -- persistence (checkpoint.py idiom: npy-per-leaf, atomic rename) ----

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"format": 2, "ties": self.ties, "arrays": {}}
        for name in _ARRAY_FIELDS:
            arr = getattr(self, name)
            if arr is None:
                continue
            arr = np.asarray(arr)
            leaf = os.path.join(tmp, f"{name}.npy")
            np.save(leaf, arr)
            manifest["arrays"][name] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha256_file(leaf)}
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        # overwrite by renaming the live artifact aside first: a crash at
        # any point leaves either the old or the new dir fully intact
        # (never an rmtree'd hole where the served artifact used to be)
        old = path + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        if os.path.exists(path):
            os.rename(path, old)
        os.rename(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
        return path

    @classmethod
    def load(cls, path: str, verify: bool = True) -> "SurvivalModel":
        """Load an artifact, verifying per-leaf sha256 checksums when the
        manifest carries them (format >= 2). A missing, truncated, or
        bit-flipped leaf raises ``ArtifactCorrupt`` naming the leaf —
        never a silently-wrong served model."""
        try:
            with open(os.path.join(path, _MANIFEST)) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            raise ArtifactCorrupt(
                f"artifact {path!r}: unreadable manifest ({e})") from e
        arrays = {}
        for name, spec in manifest["arrays"].items():
            leaf = os.path.join(path, f"{name}.npy")
            if not os.path.exists(leaf):
                raise ArtifactCorrupt(
                    f"artifact {path!r}: missing leaf {name}.npy")
            want = spec.get("sha256") if isinstance(spec, dict) else None
            if verify and want is not None:
                got = _sha256_file(leaf)
                if got != want:
                    raise ArtifactCorrupt(
                        f"artifact {path!r}: checksum mismatch on "
                        f"{name}.npy (manifest {want[:12]}..., file "
                        f"{got[:12]}...) — truncated or corrupted leaf")
            try:
                arrays[name] = np.load(leaf)
            except (OSError, ValueError) as e:
                raise ArtifactCorrupt(
                    f"artifact {path!r}: unreadable leaf {name}.npy "
                    f"({e})") from e
        return cls(ties=manifest["ties"], **arrays)


# ---------------------------------------------------------------------------
# Baseline hazard estimation (JAX, O(n) suffix scans)
# ---------------------------------------------------------------------------

def _cumhaz_samples(ts: jnp.ndarray, delta: jnp.ndarray,
                    eta: jnp.ndarray, ties: str) -> jnp.ndarray:
    """Per-sample cumulative baseline hazard on *time-sorted* data:
    H0_k = sum_{i <= k} delta_i / S0_i (Breslow) with the stabilized-w
    bookkeeping of cox.risk_stats. Returns (n,)."""
    m = jnp.max(eta)
    w = jnp.exp(eta - m)
    rc0 = cox.revcumsum(w)
    first = jnp.searchsorted(ts, ts, side="left")
    s0 = rc0[first]
    if ties == "breslow":
        inc = delta / s0
    elif ties == "efron":
        # Tie groups are contiguous on the sorted axis, so the per-group
        # quantities are O(n) segment sums via cumsum gathers at each
        # group's first/last index (no (n, n) tie matrix):
        #   j_rank = events strictly before me within my group
        #   wd     = group's event-hazard sum,  d_cnt = group's event count
        last = jnp.searchsorted(ts, ts, side="right") - 1
        cd = jnp.cumsum(delta)
        cwd = jnp.cumsum(delta * w)
        j_rank = (cd - delta) - (cd[first] - delta[first])
        wd = cwd[last] - (cwd[first] - (delta * w)[first])
        d_cnt = jnp.maximum(cd[last] - (cd[first] - delta[first]), 1.0)
        s0_eff = s0 - (j_rank / d_cnt) * wd
        inc = delta / jnp.maximum(s0_eff, 1e-30)
    else:
        raise ValueError(f"unknown tie handling: {ties!r}")
    return jnp.cumsum(inc) * jnp.exp(-m)


def _cumhaz_on_grid(t: np.ndarray, delta: np.ndarray, eta: np.ndarray,
                    grid: np.ndarray, ties: str) -> np.ndarray:
    """H0 evaluated at each grid point (right-continuous step function)."""
    order = np.argsort(t, kind="stable")
    ts = jnp.asarray(t[order])
    h_samples = np.asarray(_cumhaz_samples(
        ts, jnp.asarray(delta[order]), jnp.asarray(eta[order]), ties))
    ts_np = np.asarray(t[order], np.float64)
    idx = np.searchsorted(ts_np, np.asarray(grid, np.float64),
                          side="right") - 1
    return np.where(idx >= 0, h_samples[np.clip(idx, 0, len(ts_np) - 1)],
                    0.0).astype(np.float32)


def fit_survival_model(x: np.ndarray, t: np.ndarray, delta: np.ndarray,
                       beta: np.ndarray, *,
                       strata: Optional[np.ndarray] = None,
                       time_grid: Optional[np.ndarray] = None,
                       grid_size: int = 128, ties: str = "breslow",
                       support_tol: float = 1e-8) -> SurvivalModel:
    """Build the serving artifact from training data and a fitted beta.

    ``strata`` (n,) int labels produce one baseline row per stratum (risk
    sets never cross strata, matching core/stratified.py). The default
    ``time_grid`` spans the observed times with ``grid_size`` points.
    """
    x = np.asarray(x, np.float32)
    t = np.asarray(t, np.float32)
    delta = np.asarray(delta, np.float32)
    beta = np.asarray(beta, np.float32)
    eta = np.asarray(jnp.asarray(x) @ jnp.asarray(beta), np.float32)
    if time_grid is None:
        time_grid = np.linspace(float(t.min()), float(t.max()),
                                grid_size, dtype=np.float32)
    else:
        time_grid = np.asarray(time_grid, np.float32)

    strata_labels = None
    if strata is None:
        base = _cumhaz_on_grid(t, delta, eta, time_grid, ties)[None, :]
    else:
        strata = np.asarray(strata)
        strata_labels = np.unique(strata)
        rows = []
        for s in strata_labels:
            msk = strata == s
            rows.append(_cumhaz_on_grid(t[msk], delta[msk], eta[msk],
                                        time_grid, ties))
        base = np.stack(rows, axis=0)

    nz = np.flatnonzero(np.abs(beta) > support_tol)
    support = beta_support = None
    if len(nz) < beta.shape[0]:
        support = nz.astype(np.int32)
        beta_support = beta[nz]
    return SurvivalModel(beta=beta, time_grid=time_grid,
                         base_cumhaz=base.astype(np.float32), ties=ties,
                         support=support, beta_support=beta_support,
                         strata_labels=strata_labels)
