"""Deterministic fault injection for the serving stack.

Every failure mode the robustness layer claims to survive gets a seeded,
reproducible injector here, so tests and the overload benchmark can
*prove* graceful degradation instead of asserting it:

``ChaosEngine``
    A transparent proxy around a ``ScoringEngine`` that injects, per
    ``score()`` call: raised exceptions (``EngineFault``) and latency
    spikes (``time.sleep``). Faults are driven either by an explicit
    schedule (``fail_next(n)`` / ``spike_next(n, dur)`` — exact, for
    retry/backoff tests) or by a seeded RNG (``error_rate`` /
    ``spike_rate`` — statistically reproducible for soak runs). All
    other attributes delegate to the wrapped engine, so a ``RiskService``
    or ``ModelRegistry`` can't tell the difference.

``corrupt_artifact``
    Deterministically damages one ``.npy`` leaf of a saved
    ``SurvivalModel`` (truncate, or flip a seeded byte) so loads must
    fail with ``ArtifactCorrupt`` — the checksum-verification fixture.

``flood``
    Queue pressure: N submitter threads push requests as fast as the
    service admits them, returning per-outcome counts (admitted / shed
    at the queue). Drives the shed-low-first admission policy tests.

Nothing here is imported by production paths; it lives in ``serving/``
because the injectors are part of the subsystem's contract — every
release of the robustness layer must keep passing under them.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional

import numpy as np

from .service import Priority, QueueFull


class EngineFault(RuntimeError):
    """An injected (synthetic, transient-looking) engine failure."""


class ChaosEngine:
    """Fault-injecting proxy: quacks like the wrapped ScoringEngine."""

    def __init__(self, engine, *, seed: int = 0, error_rate: float = 0.0,
                 spike_rate: float = 0.0, spike_s: float = 0.05):
        self._engine = engine
        self._rng = np.random.default_rng(seed)
        self.error_rate = float(error_rate)
        self.spike_rate = float(spike_rate)
        self.spike_s = float(spike_s)
        self._fail_queue = 0           # scheduled exact failures
        self._spike_queue = 0          # scheduled exact spikes
        self._spike_queue_s = 0.0
        self._lock = threading.Lock()
        self.calls = 0
        self.faults_injected = 0
        self.spikes_injected = 0

    # -- scheduling (exact, for deterministic tests) -----------------------

    def fail_next(self, n: int = 1) -> None:
        """The next ``n`` score() calls raise ``EngineFault``."""
        with self._lock:
            self._fail_queue += int(n)

    def spike_next(self, n: int = 1, dur_s: Optional[float] = None) -> None:
        """The next ``n`` score() calls sleep ``dur_s`` before scoring."""
        with self._lock:
            self._spike_queue += int(n)
            self._spike_queue_s = float(dur_s if dur_s is not None
                                        else self.spike_s)

    # -- the injected call site --------------------------------------------

    def score(self, x, strata=None, with_curves: bool = False):
        with self._lock:
            self.calls += 1
            fail = self._fail_queue > 0
            if fail:
                self._fail_queue -= 1
            spike = self._spike_queue > 0
            spike_s = self._spike_queue_s
            if spike:
                self._spike_queue -= 1
            if not fail and self.error_rate > 0:
                fail = bool(self._rng.random() < self.error_rate)
            if not spike and self.spike_rate > 0:
                spike = bool(self._rng.random() < self.spike_rate)
                spike_s = self.spike_s
        if spike:
            with self._lock:
                self.spikes_injected += 1
            time.sleep(spike_s)
        if fail:
            with self._lock:
                self.faults_injected += 1
            raise EngineFault(
                f"injected engine failure (call {self.calls})")
        return self._engine.score(x, strata, with_curves=with_curves)

    def __getattr__(self, name):
        # everything else (cache_info, prewarm, feature_dim, model, ...)
        # is the wrapped engine's business
        return getattr(self._engine, name)


def corrupt_artifact(path: str, leaf: str = "beta",
                     mode: str = "truncate", seed: int = 0) -> str:
    """Deterministically damage one leaf of a saved artifact.

    ``mode="truncate"`` drops the trailing half of the ``.npy`` file (a
    crashed copy); ``mode="flip"`` XOR-flips one seeded byte past the npy
    header (silent bit rot). Returns the damaged leaf path. Loading the
    artifact afterwards must raise ``ArtifactCorrupt``.
    """
    leaf_path = os.path.join(path, f"{leaf}.npy")
    size = os.path.getsize(leaf_path)
    if mode == "truncate":
        with open(leaf_path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    elif mode == "flip":
        # stay past the ~128-byte npy header so shape/dtype still parse:
        # the *values* are wrong, which only the checksum can catch
        off = 128 + int(np.random.default_rng(seed).integers(
            0, max(size - 129, 1)))
        with open(leaf_path, "r+b") as f:
            f.seek(min(off, size - 1))
            b = f.read(1)
            f.seek(min(off, size - 1))
            f.write(bytes([b[0] ^ 0xFF]))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return leaf_path


def flood(service, n_per_thread: int, *, n_threads: int = 4,
          priority: Priority = Priority.LOW, feature_dim: int = 8,
          deadline_s: Optional[float] = None, seed: int = 0) -> dict:
    """Queue pressure: hammer ``submit()`` from ``n_threads`` concurrent
    producers. Returns ``{"rids": [...], "admitted": int, "rejected":
    int}`` — every request is accounted for (admitted or shed at the
    queue), which the pressure tests reconcile against the service's own
    counters."""
    rids_by_thread = [[] for _ in range(n_threads)]
    rejected = [0] * n_threads

    def produce(slot):
        rng = np.random.default_rng(seed + slot)
        for _ in range(n_per_thread):
            feats = rng.standard_normal(feature_dim).astype(np.float32)
            try:
                rids_by_thread[slot].append(
                    service.submit(feats, priority=priority,
                                   deadline_s=deadline_s))
            except QueueFull:
                rejected[slot] += 1

    threads = [threading.Thread(target=produce, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rids = [rid for slot in rids_by_thread for rid in slot]
    return {"rids": rids, "admitted": len(rids),
            "rejected": int(sum(rejected))}
