"""Continuous micro-batching risk API over a ScoringEngine.

Mirrors launch/serve.py's request-queue loop, but for scoring: requests
land in a thread-safe queue; each ``step()`` drains up to ``max_batch`` of
them, pads the stacked features to the engine's power-of-two bucket, runs
one jit'd scoring call, and stamps per-request latency. ``start()`` runs
the same loop on a background thread (the "continuous" mode: whatever has
queued since the last step forms the next micro-batch — exactly the
dynamic-batch policy of the LM serving loop, minus the decode recurrence).

Instrumentation: per-request latency (submit -> response), micro-batch
size histogram, throughput, and the engine's jit-cache counters, so
bucketing regressions show up as compile-count blowups in stats().
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Deque, Dict, Optional

import numpy as np

from .engine import ScoringEngine


@dataclasses.dataclass
class ScoreRequest:
    rid: int
    features: np.ndarray                 # (p,) or pre-gathered (k,)
    stratum: int = 0
    t_submit: float = 0.0


@dataclasses.dataclass
class ScoreResponse:
    rid: int
    risk: float
    median: float
    curve: Optional[np.ndarray]
    latency_s: float


class RiskService:
    """Queue + micro-batch drain loop with latency instrumentation."""

    def __init__(self, engine: ScoringEngine, *, max_batch: int = 64,
                 return_curves: bool = False, stats_window: int = 65536):
        self.engine = engine
        self.max_batch = max_batch
        self.return_curves = return_curves
        self._q: "queue.Queue[ScoreRequest]" = queue.Queue()
        self._results: Dict[int, ScoreResponse] = {}
        self._lock = threading.Lock()
        self._rid = 0
        # bounded windows: a long-running continuous service must not grow
        # its instrumentation (or delivered results) without bound
        self._batch_sizes: Deque[int] = collections.deque(
            maxlen=stats_window)
        self._latencies: Deque[float] = collections.deque(
            maxlen=stats_window)
        self._n_served = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- request side ------------------------------------------------------

    def submit(self, features: np.ndarray, stratum: int = 0) -> int:
        with self._lock:
            rid = self._rid
            self._rid += 1
            if self._t_first is None:
                self._t_first = time.perf_counter()
        self._q.put(ScoreRequest(rid=rid,
                                 features=np.asarray(features, np.float32),
                                 stratum=stratum,
                                 t_submit=time.perf_counter()))
        return rid

    def result(self, rid: int) -> Optional[ScoreResponse]:
        """Retrieve (and hand over) a scored response. The response is
        popped so delivered results don't accumulate in a long-running
        service; a second call for the same rid returns None."""
        with self._lock:
            return self._results.pop(rid, None)

    def wait(self, rid: int, timeout: float = 30.0) -> ScoreResponse:
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            out = self.result(rid)
            if out is not None:
                return out
            time.sleep(1e-4)
        raise TimeoutError(f"request {rid} not scored within {timeout}s")

    # -- serving side ------------------------------------------------------

    def step(self) -> int:
        """Score one micro-batch (whatever is queued, capped at max_batch).
        Returns the number of requests served."""
        reqs: List[ScoreRequest] = []
        while len(reqs) < self.max_batch:
            try:
                reqs.append(self._q.get_nowait())
            except queue.Empty:
                break
        if not reqs:
            return 0
        x = np.stack([r.features for r in reqs])
        strata = np.asarray([r.stratum for r in reqs], np.int32)
        out = self.engine.score(x, strata, with_curves=self.return_curves)
        risks, medians = out[0], out[1]
        curves = out[2] if self.return_curves else None
        t_done = time.perf_counter()
        with self._lock:
            self._batch_sizes.append(len(reqs))
            self._n_served += len(reqs)
            self._t_last = t_done
            for i, r in enumerate(reqs):
                lat = t_done - r.t_submit
                self._latencies.append(lat)
                self._results[r.rid] = ScoreResponse(
                    rid=r.rid, risk=float(risks[i]),
                    median=float(medians[i]),
                    curve=None if curves is None else curves[i],
                    latency_s=lat)
        return len(reqs)

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests served."""
        total = 0
        while True:
            n = self.step()
            if n == 0:
                return total
            total += n

    def start(self, poll_s: float = 1e-4):
        """Continuous mode: drain micro-batches on a background thread."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                if self.step() == 0:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=_loop, daemon=True)
        self._thread.start()

    def stop(self):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- instrumentation ---------------------------------------------------

    def stats(self) -> dict:
        """Served-request counters, throughput, and windowed latency
        percentiles (over the last ``stats_window`` requests)."""
        with self._lock:
            lats = np.asarray(self._latencies)
            n = self._n_served
            wall = ((self._t_last - self._t_first)
                    if (self._t_first is not None
                        and self._t_last is not None) else 0.0)
            sizes = list(self._batch_sizes)
        out = {"n_requests": n, "wall_s": wall,
               "reqs_per_s": (n / wall) if wall > 0 else float("nan"),
               "n_batches": len(sizes),
               "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
               "engine": self.engine.cache_info()}
        if len(lats):
            out["latency_p50_ms"] = float(np.percentile(lats, 50) * 1e3)
            out["latency_p99_ms"] = float(np.percentile(lats, 99) * 1e3)
        return out
