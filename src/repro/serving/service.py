"""Continuous micro-batching risk API over a ScoringEngine.

Mirrors launch/serve.py's request-queue loop, but for scoring: requests
land in thread-safe per-priority queues; each ``step()`` drains up to
``max_batch`` of them, pads the stacked features to the engine's
power-of-two bucket, runs one jit'd scoring call, and stamps per-request
latency. ``start()`` runs the same loop on a background thread (the
"continuous" mode: whatever has queued since the last step forms the next
micro-batch — exactly the dynamic-batch policy of the LM serving loop,
minus the decode recurrence).

Admission control & overload behavior
-------------------------------------
Two priority classes (``Priority.HIGH`` / ``Priority.LOW``, default LOW)
with strict-priority dequeue and a *shed-low-first* policy: when the
bounded queue (``max_queue``) is full, a HIGH submit evicts the newest
queued LOW request (the one with the least queue time invested) — the
victim's waiter is woken with an ``error="shed"`` response, never
silently lost — while a same-or-lower-priority submit raises
``QueueFull``. Per-request deadlines (``submit(..., deadline_s=...)``)
are enforced *server-side*: an expired request is dropped at batch-form
time with an ``error="deadline_exceeded"`` response instead of wasting a
jit dispatch on an answer nobody will read. Together these keep HIGH p99
bounded past saturation (see ``benchmarks/bench_overload.py``).

Crash safety & health
---------------------
A scoring exception never kills the drain thread: the dispatch is
retried with bounded exponential backoff (``retries`` / ``retry_backoff_s``,
for transient engine faults), and if all attempts fail every request in
the batch gets an ``error=...`` response. The service exposes a readiness
surface — ``health()`` is ``SERVING`` (healthy), ``DEGRADED`` (a recent
dispatch failed or is being retried), or ``DOWN`` (``down_after``
consecutive batches failed after retries) — mirrored into the
``service_health_state`` one-hot gauge; any fully successful batch
returns it to ``SERVING``.

Results lifecycle
-----------------
``wait()`` blocks on a ``threading.Condition`` signaled by ``step()``
(no busy-poll). A ``wait()`` that times out raises ``ScoreTimeout`` and
*abandons* the request: if still queued it is dropped at batch-form
time, and an already-stored response is evicted, so ``_results`` never
accumulates responses nobody will collect. A TTL sweep
(``result_ttl_s``) additionally evicts responses that were never waited
on, keeping a long-running service bounded.

Hot swap
--------
``set_engine()`` atomically replaces the live engine between batches
(the in-flight batch finishes on the engine it started with); it is the
slot ``serving/registry.py`` swaps freshly warmed models into, with zero
dropped requests.

Telemetry (``repro.obs``): every batch is one trace — a ``service.step``
root span with ``service.batch_form`` / ``service.dispatch`` /
``service.respond`` children plus one retroactive ``service.request``
span per request. Always-on metrics: queue-depth gauge, health state
gauge, batch-size and latency histograms, served / rejected / shed /
expired / timeout / retry / engine-failure counters.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from ..obs import trace
from .engine import ScoringEngine


class Priority(enum.IntEnum):
    """Two admission classes: HIGH is dequeued first and may evict queued
    LOW work at a full queue (shed-low-first); LOW is best-effort."""

    HIGH = 0
    LOW = 1


HEALTH_STATES = ("SERVING", "DEGRADED", "DOWN")


class ScoreTimeout(TimeoutError):
    """``wait()`` deadline passed before the request was scored. The
    request is abandoned: a late or queued response is evicted."""

    def __init__(self, rid: int, timeout: float):
        super().__init__(f"request {rid} not scored within {timeout}s")
        self.rid = rid
        self.timeout = timeout


class QueueFull(RuntimeError):
    """``submit()`` shed the request: the bounded queue is at capacity
    and the request's priority class cannot evict anything."""

    def __init__(self, max_queue: int):
        super().__init__(f"request shed: queue at capacity ({max_queue})")
        self.max_queue = max_queue


@dataclasses.dataclass
class ScoreRequest:
    rid: int
    features: np.ndarray                 # (p,) or pre-gathered (k,)
    stratum: int = 0
    t_submit: float = 0.0
    priority: Priority = Priority.LOW
    deadline: Optional[float] = None     # absolute perf_counter time


@dataclasses.dataclass
class ScoreResponse:
    rid: int
    risk: float
    median: float
    curve: Optional[np.ndarray]
    latency_s: float
    trace_id: Optional[str] = None       # the batch's trace, when tracing
    error: Optional[str] = None          # terminal failure, when not scored

    @property
    def ok(self) -> bool:
        return self.error is None

    @classmethod
    def failure(cls, rid: int, error: str,
                latency_s: float = 0.0) -> "ScoreResponse":
        return cls(rid=rid, risk=float("nan"), median=float("nan"),
                   curve=None, latency_s=latency_s, error=error)


class RiskService:
    """Priority queues + micro-batch drain loop with admission control,
    crash-safe dispatch, and latency instrumentation."""

    def __init__(self, engine: ScoringEngine, *, max_batch: int = 64,
                 return_curves: bool = False, stats_window: int = 65536,
                 max_queue: Optional[int] = None,
                 retries: int = 2, retry_backoff_s: float = 0.05,
                 max_backoff_s: float = 1.0, down_after: int = 3,
                 result_ttl_s: float = 60.0,
                 registry: Optional[obs_metrics.Registry] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.return_curves = return_curves
        self.max_queue = max_queue
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.down_after = int(down_after)
        self.result_ttl_s = float(result_ttl_s)
        # one mutex guards queues, results, counters, health, and the
        # engine slot; two conditions on it signal new work (the drain
        # loop) and posted results (wait()ers) — no busy-polling anywhere
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._done = threading.Condition(self._lock)
        self._queues: Dict[Priority, Deque[ScoreRequest]] = {
            Priority.HIGH: collections.deque(),
            Priority.LOW: collections.deque()}
        self._results: Dict[int, Tuple[float, ScoreResponse]] = {}
        self._abandoned: set = set()
        self._rid = 0
        self._health = "SERVING"
        self._consec_failures = 0
        self.engine_swaps = 0
        self._last_sweep = time.perf_counter()
        # bounded windows: a long-running continuous service must not grow
        # its instrumentation (or delivered results) without bound
        self._batch_sizes: Deque[int] = collections.deque(
            maxlen=stats_window)
        self._latencies: Deque[float] = collections.deque(
            maxlen=stats_window)
        self._n_served = 0
        self._n_rejected = 0
        self._n_timeouts = 0
        self._n_shed = 0
        self._n_expired = 0
        self._n_errors = 0
        self._n_retries = 0
        self._n_engine_failures = 0
        self._n_evicted = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        reg = registry if registry is not None else obs_metrics.REGISTRY
        self._m_served = reg.counter(
            "service_requests_total", "requests scored")
        self._m_rejected = reg.counter(
            "service_rejected_total", "requests shed at a full queue")
        self._m_timeouts = reg.counter(
            "service_timeouts_total", "wait() deadlines missed")
        self._m_shed = reg.counter(
            "service_shed_total", "queued LOW requests evicted by HIGH")
        self._m_expired = reg.counter(
            "service_deadline_expired_total",
            "requests dropped at batch-form time past their deadline")
        self._m_errors = reg.counter(
            "service_error_responses_total",
            "requests answered with an error after dispatch failure")
        self._m_retries = reg.counter(
            "service_dispatch_retries_total",
            "engine dispatch retries after transient failures")
        self._m_engine_failures = reg.counter(
            "service_engine_failures_total",
            "batches that failed after exhausting retries")
        self._m_evicted = reg.counter(
            "service_results_evicted_total",
            "responses evicted uncollected (timeout abandon or TTL)")
        self._m_swaps = reg.counter(
            "service_engine_swaps_total", "live engine hot-swaps")
        self._m_health = reg.gauge(
            "service_health_state", "readiness one-hot (SERVING/DEGRADED/"
            "DOWN)", ("state",))
        self._m_health.set_state(self._health, HEALTH_STATES)
        self._m_depth = reg.gauge(
            "service_queue_depth", "requests waiting in the queue")
        # callback gauge: depth is read at scrape/snapshot time, the
        # submit/step hot paths never touch it
        self._m_depth.set_fn(self._depth)
        self._m_batch = reg.histogram(
            "service_batch_size", "micro-batch sizes",
            buckets=obs_metrics.POW2_BUCKETS)
        self._m_latency = reg.histogram(
            "service_latency_seconds", "submit -> response latency")
        self._m_queue_wait = reg.histogram(
            "service_queue_wait_seconds", "submit -> batch-form wait")

    # -- request side ------------------------------------------------------

    def _depth(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def submit(self, features: np.ndarray, stratum: int = 0, *,
               priority: Priority = Priority.LOW,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid.

        ``deadline_s`` is a server-side budget: past it the request is
        dropped at batch-form time with an ``error="deadline_exceeded"``
        response. At a full queue a HIGH submit evicts the newest queued
        LOW request (its waiter gets an ``error="shed"`` response);
        otherwise ``QueueFull`` is raised.
        """
        priority = Priority(priority)
        now = time.perf_counter()
        feats = np.asarray(features, np.float32)
        shed_victim: Optional[ScoreRequest] = None
        with self._lock:
            if self.max_queue and self._depth() >= self.max_queue:
                if (priority == Priority.HIGH
                        and self._queues[Priority.LOW]):
                    # shed-low-first: evict the newest LOW arrival (least
                    # queue time invested) to admit the HIGH request
                    shed_victim = self._queues[Priority.LOW].pop()
                else:
                    self._n_rejected += 1
                    self._m_rejected.inc()
                    raise QueueFull(self.max_queue)
            rid = self._rid
            self._rid += 1
            if self._t_first is None:
                self._t_first = now
            req = ScoreRequest(
                rid=rid, features=feats, stratum=stratum, t_submit=now,
                priority=priority,
                deadline=None if deadline_s is None else now + deadline_s)
            self._queues[priority].append(req)
            if shed_victim is not None:
                self._n_shed += 1
                self._post_locked(shed_victim.rid, ScoreResponse.failure(
                    shed_victim.rid, "shed",
                    latency_s=now - shed_victim.t_submit))
            self._work.notify()
        if shed_victim is not None:
            self._m_shed.inc()
        return rid

    def result(self, rid: int) -> Optional[ScoreResponse]:
        """Retrieve (and hand over) a scored response. The response is
        popped so delivered results don't accumulate in a long-running
        service; a second call for the same rid returns None."""
        with self._lock:
            entry = self._results.pop(rid, None)
            return entry[1] if entry is not None else None

    def wait(self, rid: int, timeout: float = 30.0) -> ScoreResponse:
        """Block until rid's response is posted (condition-signaled; no
        spin). On timeout, raises ``ScoreTimeout`` and abandons the
        request — a queued copy is dropped at batch-form time and a late
        response is evicted rather than stored forever."""
        deadline = time.perf_counter() + timeout
        with self._done:
            while True:
                entry = self._results.pop(rid, None)
                if entry is not None:
                    return entry[1]
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    self._n_timeouts += 1
                    self._abandoned.add(rid)
                    break
                self._done.wait(remaining)
        self._m_timeouts.inc()
        raise ScoreTimeout(rid, timeout)

    # -- serving side ------------------------------------------------------

    def _post_locked(self, rid: int, resp: ScoreResponse) -> None:
        """Store (or drop, if abandoned) one terminal response and wake
        waiters. Caller holds ``self._lock``."""
        if rid in self._abandoned:
            self._abandoned.discard(rid)
            self._n_evicted += 1
            self._m_evicted.inc()
        else:
            self._results[rid] = (time.perf_counter(), resp)
        if resp.error is not None:
            self._n_errors += 1
            self._m_errors.inc()
        self._done.notify_all()

    def _sweep_locked(self, now: float) -> None:
        """TTL-evict responses nobody collected. Caller holds the lock."""
        if now - self._last_sweep < max(self.result_ttl_s / 4.0, 0.25):
            return
        self._last_sweep = now
        dead = [rid for rid, (t_post, _) in self._results.items()
                if now - t_post > self.result_ttl_s]
        for rid in dead:
            del self._results[rid]
        if dead:
            self._n_evicted += len(dead)
            self._m_evicted.inc(len(dead))

    def _form_batch(self) -> Tuple[List[ScoreRequest], int, int]:
        """Pop up to max_batch requests, HIGH before LOW, dropping
        expired or abandoned ones with terminal outcomes. Returns
        (batch, n_expired, n_abandoned)."""
        reqs: List[ScoreRequest] = []
        n_expired = n_abandoned = 0
        now = time.perf_counter()
        with self._lock:
            for prio in (Priority.HIGH, Priority.LOW):
                q = self._queues[prio]
                while q and len(reqs) < self.max_batch:
                    req = q.popleft()
                    if req.rid in self._abandoned:
                        # waiter gave up: skip the jit work entirely
                        self._abandoned.discard(req.rid)
                        self._n_evicted += 1
                        n_abandoned += 1
                        continue
                    if req.deadline is not None and now > req.deadline:
                        self._n_expired += 1
                        n_expired += 1
                        self._post_locked(req.rid, ScoreResponse.failure(
                            req.rid, "deadline_exceeded",
                            latency_s=now - req.t_submit))
                        continue
                    reqs.append(req)
                if len(reqs) >= self.max_batch:
                    break
            self._sweep_locked(now)
        if n_expired:
            self._m_expired.inc(n_expired)
        if n_abandoned:
            self._m_evicted.inc(n_abandoned)
        return reqs, n_expired, n_abandoned

    def _set_health(self, state: str) -> None:
        if state != self._health:
            self._health = state
            obs_events.emit("service.health", state=state,
                            consec_failures=self._consec_failures)
        self._m_health.set_state(state, HEALTH_STATES)

    def _dispatch(self, x: np.ndarray, strata: np.ndarray):
        """One engine call with bounded exponential-backoff retries.
        Returns the engine output or raises the last failure."""
        engine = self.engine        # snapshot: hot-swap safe per batch
        attempt = 0
        while True:
            try:
                out = engine.score(x, strata,
                                   with_curves=self.return_curves)
                if attempt > 0:
                    obs_events.emit("service.retry_recovered",
                                    attempts=attempt + 1)
                return out
            except Exception:
                with self._lock:
                    self._set_health("DEGRADED")
                if attempt >= self.retries:
                    raise
                backoff = min(self.retry_backoff_s * (2.0 ** attempt),
                              self.max_backoff_s)
                attempt += 1
                with self._lock:
                    self._n_retries += 1
                self._m_retries.inc()
                time.sleep(backoff)

    def step(self) -> int:
        """Score one micro-batch (whatever is queued, capped at
        max_batch). Returns the number of requests *scored*; expired,
        abandoned, or failed requests resolve to terminal responses but
        don't count. Never raises on engine failure: the batch turns
        into per-request error responses and a health transition."""
        if not self._depth():    # idle poll: no spans for empty steps
            return 0
        with trace.span("service.step") as step_span:
            with trace.span("service.batch_form"):
                reqs, _, _ = self._form_batch()
                if not reqs:
                    return 0
                t_formed = time.perf_counter()
                x = np.stack([r.features for r in reqs])
                strata = np.asarray([r.stratum for r in reqs], np.int32)
            step_span.set(batch=len(reqs))
            try:
                with trace.span("service.dispatch", batch=len(reqs)):
                    out = self._dispatch(x, strata)
            except Exception as e:
                # crash-safe: the batch resolves to error responses, the
                # drain loop lives on, and readiness degrades instead of
                # the thread dying silently
                err = f"{type(e).__name__}: {e}"
                step_span.set(error=type(e).__name__)
                t_fail = time.perf_counter()
                with self._lock:
                    self._n_engine_failures += 1
                    self._consec_failures += 1
                    self._set_health(
                        "DOWN" if self._consec_failures >= self.down_after
                        else "DEGRADED")
                    for r in reqs:
                        self._post_locked(r.rid, ScoreResponse.failure(
                            r.rid, err, latency_s=t_fail - r.t_submit))
                self._m_engine_failures.inc()
                obs_events.emit("service.batch_failed", batch=len(reqs),
                                error=err)
                return 0
            risks, medians = out[0], out[1]
            curves = out[2] if self.return_curves else None
            with trace.span("service.respond"):
                t_done = time.perf_counter()
                traced = trace.enabled()
                with self._lock:
                    self._consec_failures = 0
                    self._set_health("SERVING")
                    self._batch_sizes.append(len(reqs))
                    self._n_served += len(reqs)
                    self._t_last = t_done
                    for i, r in enumerate(reqs):
                        lat = t_done - r.t_submit
                        self._latencies.append(lat)
                        self._post_locked(r.rid, ScoreResponse(
                            rid=r.rid, risk=float(risks[i]),
                            median=float(medians[i]),
                            curve=None if curves is None else curves[i],
                            latency_s=lat,
                            trace_id=step_span.trace_id))
                self._m_served.inc(len(reqs))
                self._m_batch.observe(len(reqs))
                subs = np.fromiter((r.t_submit for r in reqs),
                                   dtype=float, count=len(reqs))
                self._m_queue_wait.observe_many(t_formed - subs)
                self._m_latency.observe_many(t_done - subs)
                if traced:
                    for r in reqs:
                        trace.emit_span("service.request",
                                        t_done - r.t_submit, rid=r.rid,
                                        queue_wait_s=t_formed - r.t_submit)
            return len(reqs)

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests scored."""
        total = 0
        while True:
            n = self.step()
            if n == 0 and not self._depth():
                return total
            total += n

    def start(self, poll_s: float = 0.05):
        """Continuous mode: drain micro-batches on a background thread.
        The loop sleeps on a condition signaled by ``submit()`` —
        ``poll_s`` only bounds stop/TTL-sweep latency, idle CPU is ~0.
        The loop itself is crash-safe: an unexpected exception (outside
        the per-batch handling in ``step()``) degrades health and
        continues instead of killing the thread."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                try:
                    served = self.step()
                except Exception as e:     # pragma: no cover - last ditch
                    with self._lock:
                        self._set_health("DEGRADED")
                    obs_events.emit("service.loop_error",
                                    error=f"{type(e).__name__}: {e}")
                    time.sleep(min(poll_s, 0.05))
                    continue
                if served == 0:
                    with self._work:
                        if not self._depth() and not self._stop.is_set():
                            self._sweep_locked(time.perf_counter())
                            self._work.wait(poll_s)

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="risk-service-drain")
        self._thread.start()

    def stop(self):
        if self._thread is None:
            return
        self._stop.set()
        with self._work:
            self._work.notify_all()
        self._thread.join()
        self._thread = None

    @property
    def thread_alive(self) -> bool:
        """True while the background drain thread is running."""
        return self._thread is not None and self._thread.is_alive()

    # -- hot swap ----------------------------------------------------------

    def set_engine(self, engine: ScoringEngine) -> None:
        """Atomically swap the live engine between batches. The in-flight
        batch finishes on the engine it snapshotted; queued requests are
        untouched, so a rollout drops zero requests. Called by
        ``ModelRegistry.swap``."""
        with self._lock:
            self.engine = engine
            self.engine_swaps += 1
        self._m_swaps.inc()
        obs_events.emit("service.engine_swap", swaps=self.engine_swaps)

    # -- instrumentation ---------------------------------------------------

    def health(self) -> str:
        """Readiness: SERVING | DEGRADED | DOWN."""
        with self._lock:
            return self._health

    def stats(self) -> dict:
        """Served-request counters, throughput, health, and windowed
        latency percentiles (over the last ``stats_window`` requests).

        Every key is always present — before the first request completes
        the percentiles are 0.0 and the throughput NaN — so dashboards
        and tests never key-error on a fresh or idle service."""
        with self._lock:
            lats = np.asarray(self._latencies)
            n = self._n_served
            rejected = self._n_rejected
            timeouts = self._n_timeouts
            wall = ((self._t_last - self._t_first)
                    if (self._t_first is not None
                        and self._t_last is not None) else 0.0)
            sizes = list(self._batch_sizes)
            extra = {"shed_count": self._n_shed,
                     "expired_count": self._n_expired,
                     "error_count": self._n_errors,
                     "retry_count": self._n_retries,
                     "engine_failures": self._n_engine_failures,
                     "results_evicted": self._n_evicted,
                     "results_pending": len(self._results),
                     "engine_swaps": self.engine_swaps,
                     "health": self._health}
            depth = self._depth()
        out = {"n_requests": n, "wall_s": wall,
               "reqs_per_s": (n / wall) if wall > 0 else float("nan"),
               "n_batches": len(sizes),
               "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
               "queue_depth": depth,
               "rejected_count": rejected,
               "timeout_count": timeouts,
               "latency_p50_ms": (float(np.percentile(lats, 50) * 1e3)
                                  if len(lats) else 0.0),
               "latency_p99_ms": (float(np.percentile(lats, 99) * 1e3)
                                  if len(lats) else 0.0),
               "engine": self.engine.cache_info()}
        out.update(extra)
        return out
