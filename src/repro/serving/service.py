"""Continuous micro-batching risk API over a ScoringEngine.

Mirrors launch/serve.py's request-queue loop, but for scoring: requests
land in a thread-safe queue; each ``step()`` drains up to ``max_batch`` of
them, pads the stacked features to the engine's power-of-two bucket, runs
one jit'd scoring call, and stamps per-request latency. ``start()`` runs
the same loop on a background thread (the "continuous" mode: whatever has
queued since the last step forms the next micro-batch — exactly the
dynamic-batch policy of the LM serving loop, minus the decode recurrence).

Telemetry (``repro.obs``): every batch is one trace — a ``service.step``
root span with ``service.batch_form`` / ``service.dispatch`` /
``service.respond`` children plus one retroactive ``service.request``
span per request (queue wait + total latency), so the per-stage
latency-breakdown table in ``analysis/report.py`` attributes p99 to
queueing vs batching vs jit dispatch. Always-on metrics: queue-depth
gauge, batch-size and latency histograms, served / shed / timeout
counters. Spans cost one ``None`` check when tracing is off.

Overload behavior: ``max_queue`` bounds the queue — ``submit()`` beyond
it sheds the request (raises ``QueueFull``, counts it in
``service_rejected_total``). ``wait()`` past its deadline raises
``ScoreTimeout`` carrying the request id and counts it in
``service_timeouts_total``.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Deque, Dict, List, Optional

import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import trace
from .engine import ScoringEngine


class ScoreTimeout(TimeoutError):
    """``wait()`` deadline passed before the request was scored."""

    def __init__(self, rid: int, timeout: float):
        super().__init__(f"request {rid} not scored within {timeout}s")
        self.rid = rid
        self.timeout = timeout


class QueueFull(RuntimeError):
    """``submit()`` shed the request: the bounded queue is at capacity."""

    def __init__(self, max_queue: int):
        super().__init__(f"request shed: queue at capacity ({max_queue})")
        self.max_queue = max_queue


@dataclasses.dataclass
class ScoreRequest:
    rid: int
    features: np.ndarray                 # (p,) or pre-gathered (k,)
    stratum: int = 0
    t_submit: float = 0.0


@dataclasses.dataclass
class ScoreResponse:
    rid: int
    risk: float
    median: float
    curve: Optional[np.ndarray]
    latency_s: float
    trace_id: Optional[str] = None       # the batch's trace, when tracing


class RiskService:
    """Queue + micro-batch drain loop with latency instrumentation."""

    def __init__(self, engine: ScoringEngine, *, max_batch: int = 64,
                 return_curves: bool = False, stats_window: int = 65536,
                 max_queue: Optional[int] = None,
                 registry: Optional[obs_metrics.Registry] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.return_curves = return_curves
        self.max_queue = max_queue
        self._q: "queue.Queue[ScoreRequest]" = queue.Queue(
            maxsize=max_queue or 0)
        self._results: Dict[int, ScoreResponse] = {}
        self._lock = threading.Lock()
        self._rid = 0
        # bounded windows: a long-running continuous service must not grow
        # its instrumentation (or delivered results) without bound
        self._batch_sizes: Deque[int] = collections.deque(
            maxlen=stats_window)
        self._latencies: Deque[float] = collections.deque(
            maxlen=stats_window)
        self._n_served = 0
        self._n_rejected = 0
        self._n_timeouts = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        reg = registry if registry is not None else obs_metrics.REGISTRY
        self._m_served = reg.counter(
            "service_requests_total", "requests scored")
        self._m_rejected = reg.counter(
            "service_rejected_total", "requests shed at a full queue")
        self._m_timeouts = reg.counter(
            "service_timeouts_total", "wait() deadlines missed")
        self._m_depth = reg.gauge(
            "service_queue_depth", "requests waiting in the queue")
        # callback gauge: depth is read at scrape/snapshot time, the
        # submit/step hot paths never touch it
        self._m_depth.set_fn(self._q.qsize)
        self._m_batch = reg.histogram(
            "service_batch_size", "micro-batch sizes",
            buckets=obs_metrics.POW2_BUCKETS)
        self._m_latency = reg.histogram(
            "service_latency_seconds", "submit -> response latency")
        self._m_queue_wait = reg.histogram(
            "service_queue_wait_seconds", "submit -> batch-form wait")

    # -- request side ------------------------------------------------------

    def submit(self, features: np.ndarray, stratum: int = 0) -> int:
        with self._lock:
            rid = self._rid
            self._rid += 1
            if self._t_first is None:
                self._t_first = time.perf_counter()
        req = ScoreRequest(rid=rid,
                           features=np.asarray(features, np.float32),
                           stratum=stratum,
                           t_submit=time.perf_counter())
        try:
            self._q.put_nowait(req)
        except queue.Full:
            with self._lock:
                self._n_rejected += 1
            self._m_rejected.inc()
            raise QueueFull(self.max_queue) from None
        return rid

    def result(self, rid: int) -> Optional[ScoreResponse]:
        """Retrieve (and hand over) a scored response. The response is
        popped so delivered results don't accumulate in a long-running
        service; a second call for the same rid returns None."""
        with self._lock:
            return self._results.pop(rid, None)

    def wait(self, rid: int, timeout: float = 30.0) -> ScoreResponse:
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            out = self.result(rid)
            if out is not None:
                return out
            time.sleep(1e-4)
        with self._lock:
            self._n_timeouts += 1
        self._m_timeouts.inc()
        raise ScoreTimeout(rid, timeout)

    # -- serving side ------------------------------------------------------

    def step(self) -> int:
        """Score one micro-batch (whatever is queued, capped at max_batch).
        Returns the number of requests served."""
        if self._q.empty():    # idle poll: no spans for empty steps
            return 0
        with trace.span("service.step") as step_span:
            with trace.span("service.batch_form"):
                reqs: List[ScoreRequest] = []
                while len(reqs) < self.max_batch:
                    try:
                        reqs.append(self._q.get_nowait())
                    except queue.Empty:
                        break
                if not reqs:
                    return 0
                t_formed = time.perf_counter()
                x = np.stack([r.features for r in reqs])
                strata = np.asarray([r.stratum for r in reqs], np.int32)
            step_span.set(batch=len(reqs))
            with trace.span("service.dispatch", batch=len(reqs)):
                out = self.engine.score(x, strata,
                                        with_curves=self.return_curves)
                risks, medians = out[0], out[1]
                curves = out[2] if self.return_curves else None
            with trace.span("service.respond"):
                t_done = time.perf_counter()
                traced = trace.enabled()
                with self._lock:
                    self._batch_sizes.append(len(reqs))
                    self._n_served += len(reqs)
                    self._t_last = t_done
                    for i, r in enumerate(reqs):
                        lat = t_done - r.t_submit
                        self._latencies.append(lat)
                        self._results[r.rid] = ScoreResponse(
                            rid=r.rid, risk=float(risks[i]),
                            median=float(medians[i]),
                            curve=None if curves is None else curves[i],
                            latency_s=lat,
                            trace_id=step_span.trace_id)
                self._m_served.inc(len(reqs))
                self._m_batch.observe(len(reqs))
                subs = np.fromiter((r.t_submit for r in reqs),
                                   dtype=float, count=len(reqs))
                self._m_queue_wait.observe_many(t_formed - subs)
                self._m_latency.observe_many(t_done - subs)
                if traced:
                    for r in reqs:
                        trace.emit_span("service.request",
                                        t_done - r.t_submit, rid=r.rid,
                                        queue_wait_s=t_formed - r.t_submit)
            return len(reqs)

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests served."""
        total = 0
        while True:
            n = self.step()
            if n == 0:
                return total
            total += n

    def start(self, poll_s: float = 1e-4):
        """Continuous mode: drain micro-batches on a background thread."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                if self.step() == 0:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=_loop, daemon=True)
        self._thread.start()

    def stop(self):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- instrumentation ---------------------------------------------------

    def stats(self) -> dict:
        """Served-request counters, throughput, and windowed latency
        percentiles (over the last ``stats_window`` requests).

        Every key is always present — before the first request completes
        the percentiles are 0.0 and the throughput NaN — so dashboards
        and tests never key-error on a fresh or idle service."""
        with self._lock:
            lats = np.asarray(self._latencies)
            n = self._n_served
            rejected = self._n_rejected
            timeouts = self._n_timeouts
            wall = ((self._t_last - self._t_first)
                    if (self._t_first is not None
                        and self._t_last is not None) else 0.0)
            sizes = list(self._batch_sizes)
        return {"n_requests": n, "wall_s": wall,
                "reqs_per_s": (n / wall) if wall > 0 else float("nan"),
                "n_batches": len(sizes),
                "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
                "queue_depth": self._q.qsize(),
                "rejected_count": rejected,
                "timeout_count": timeouts,
                "latency_p50_ms": (float(np.percentile(lats, 50) * 1e3)
                                   if len(lats) else 0.0),
                "latency_p99_ms": (float(np.percentile(lats, 99) * 1e3)
                                   if len(lats) else 0.0),
                "engine": self.engine.cache_info()}
