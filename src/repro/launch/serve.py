"""Batched serving launcher: continuous batching over a request queue.

``python -m repro.launch.serve --arch <id> --reduced --requests 16``

prefill() builds per-request caches (batched), then a decode loop emits one
token per active sequence per step with per-sequence stop handling —
the same (jit'd) prefill/decode entry points the dry-run lowers at
production shapes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REGISTRY, get_config, reduced_config
from ..models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


def serve_batch(model, params, requests: List[Request], max_len: int = 0):
    """One batched generation round: pad prompts, prefill, decode loop."""
    bsz = len(requests)
    plen = max(len(r.prompt) for r in requests)
    toks = np.zeros((bsz, plen), np.int32)
    for i, r in enumerate(requests):
        toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
    max_new = max(r.max_new for r in requests)

    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, max_len=plen + max_new + 1))
    decode = jax.jit(model.decode_step)

    logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
    v = model.cfg.vocab_size
    nxt = jnp.argmax(logits[:, :v], axis=-1).astype(jnp.int32)
    for step in range(max_new):
        for i, r in enumerate(requests):
            if step < r.max_new:
                r.out.append(int(nxt[i]))
        logits, cache = decode(params, cache, nxt[:, None])
        nxt = jnp.argmax(logits[:, :v], axis=-1).astype(jnp.int32)
    return requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    reqs = serve_batch(model, params, reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s batched)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out}")
    return reqs


if __name__ == "__main__":
    main()
