"""Production training launcher.

``python -m repro.launch.train --arch <id> [--objective lm|cox] ...``

Wires together: config registry -> model -> sharded TrainState -> jit'd
train step -> deterministic pipeline -> heartbeat/straggler monitor ->
async checkpointing with resume. On this CPU container it runs reduced
configs end-to-end (see examples/); on a TPU fleet the same file is the
per-host entry point (jax.distributed.initialize is a no-op locally).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import REGISTRY, TrainConfig, get_config, reduced_config
from ..data.pipeline import SurvivalTextStream, TokenTaskStream, put_batch
from ..models import build_model
from ..survival.head import init_cox_head
from ..train import checkpoint as ckpt_lib
from ..train import fault_tolerance as ft
from ..train.optimizer import init_opt_state
from ..train.trainer import TrainState, make_train_step
from . import sharding as sh
from .mesh import make_host_mesh, make_production_mesh, mesh_context


def build_state(model, objective: str, rng):
    params = model.init_params(rng)
    if objective == "cox":
        params["cox_head"] = init_cox_head(jax.random.PRNGKey(7),
                                           model.cfg.d_model)
    return TrainState(params=params, opt=init_opt_state(params))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--objective", default="lm", choices=["lm", "cox"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--scale", default="",
                    help="comma k=v ModelConfig overrides, e.g. "
                         "n_layers=8,d_model=512")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.scale:
        kw = {}
        for kv in args.scale.split(","):
            k, v = kv.split("=")
            kw[k] = type(getattr(cfg, k))(v)
        cfg = cfg.scaled(**kw)
    cfg = cfg.scaled(vocab_size=min(cfg.vocab_size, 4096))
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps, microbatch=args.microbatch)
    mesh = make_production_mesh() if args.production_mesh \
        else make_host_mesh()

    stream_cls = TokenTaskStream if args.objective == "lm" \
        else SurvivalTextStream
    stream = stream_cls(cfg.vocab_size, args.seq, args.batch, seed=args.seed)

    step_fn = jax.jit(make_train_step(model, tcfg, args.objective))
    hb = ft.Heartbeat((args.ckpt_dir or "/tmp/repro") + "/heartbeat.json")
    mon = ft.StragglerMonitor()
    checkpointer = ckpt_lib.AsyncCheckpointer(args.ckpt_dir) \
        if args.ckpt_dir else None

    with mesh_context(mesh):
        init = lambda: build_state(model, args.objective,
                                   jax.random.PRNGKey(args.seed))
        if args.ckpt_dir:
            state, start = ft.resume_or_init(args.ckpt_dir, init)
            if start:
                print(f"[train] resumed from step {start}")
        else:
            state, start = init(), 0

        losses = []
        for step in range(start, args.steps):
            t0 = time.time()
            batch = put_batch(stream.batch_for_step(step), mesh)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            straggler = mon.record(dt)
            hb.beat(step, {"loss": loss})
            if step % args.log_every == 0 or straggler:
                tag = " STRAGGLER" if straggler else ""
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms{tag}", flush=True)
            if checkpointer and (step + 1) % args.ckpt_every == 0:
                checkpointer.save(step + 1, state)
        if checkpointer:
            checkpointer.save(args.steps, state)
            checkpointer.wait()
    print(f"[train] done: first-10 mean {np.mean(losses[:10]):.4f} "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")
    return state, losses


if __name__ == "__main__":
    main()
