"""PartitionSpec rules for params, optimizer state, batches, and caches.

Policy (DESIGN.md §5):
  * last dim of every >=2-D weight -> ``model`` (TP) when divisible;
  * second-to-last dim -> ``data`` (FSDP/ZeRO-3 style) in *train* mode when
    divisible — required to fit the 67B/141B archs' params+optimizer into
    16 GB/chip; serving uses TP-only params (latency: no per-layer gather);
  * token embeddings: vocab over ``model``;
  * stacked-layer leading dims are never sharded;
  * anything indivisible falls back to replication on that dim (e.g.
    qwen1.5's 20 heads: the flattened 2560-wide QKV dim shards 16-way even
    though 20 heads don't — XLA repartitions around the per-head reshape).

Batches: batch dim over ("pod","data"); decode KV caches: batch over
``data`` and the cache sequence dim over ``model`` (the flash-decode
partition; kv-head counts in the pool are all < 16 so head-sharding the
cache is not an option).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import mesh as mesh_lib


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def param_spec(path: tuple, shape: tuple, mesh: Mesh, mode: str = "train"):
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1] if names else ""
    stacked = "layers" in names or "enc_layers" in names
    msize = _axsize(mesh, "model")
    dsize = _axsize(mesh, "data")
    fsdp = mesh_lib.fsdp_axis(mesh) if mode == "train" else None

    if name == "embed":
        v_ax = "model" if shape[0] % msize == 0 else None
        d_ax = fsdp if (fsdp and shape[1] % dsize == 0) else None
        return P(v_ax, d_ax)

    spec = [None] * len(shape)
    if len(shape) >= 2:
        # skip leading stack dims: only the trailing 2 dims are sharded;
        # small leaves (norm scales, biases) stay replicated
        last, second = len(shape) - 1, len(shape) - 2
        if shape[last] % msize == 0 and shape[last] >= 1024:
            spec[last] = "model"
        if fsdp and shape[second] % dsize == 0 and shape[second] >= 1024 \
                and (second > 0 or not stacked):
            spec[second] = fsdp
    elif len(shape) == 1:
        if shape[0] % msize == 0 and shape[0] >= 4096:
            spec[0] = "model"
    return P(*spec)


def param_shardings(params_shape: Any, mesh: Mesh, mode: str = "train"):
    """Pytree of NamedShardings matching a params eval_shape pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf.shape, mesh, mode)),
        params_shape)


def batch_spec(path: tuple, shape: tuple, mesh: Mesh):
    dp = mesh_lib.dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    name = getattr(path[-1], "key", "") if path else ""
    if name == "positions" and len(shape) == 3:   # (3, B, S) M-RoPE
        return P(None, dp if shape[1] % n_dp == 0 else None, None)
    spec = [None] * len(shape)
    if shape and shape[0] % n_dp == 0:
        spec[0] = dp
    return P(*spec)


def batch_shardings(batch_specs: Any, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, batch_spec(path, leaf.shape, mesh)),
        batch_specs)


def cache_spec(path: tuple, shape: tuple, mesh: Mesh):
    """Decode caches: (L, B, S, KH, hd) -> batch over data, seq over model
    (flash-decode layout); SSM states (L, B, H, hd, N): batch over data,
    heads over model when divisible."""
    name = getattr(path[-1], "name", getattr(path[-1], "key", "")) if path \
        else ""
    msize = _axsize(mesh, "model")
    dsize = _axsize(mesh, "data")
    if name == "length":
        return P(None)
    spec = [None] * len(shape)
    if len(shape) >= 2 and shape[1] % dsize == 0:
        spec[1] = "data"
    if name in ("k", "v", "xk", "xv") and len(shape) == 5:
        if shape[2] % msize == 0:
            spec[2] = "model"
    elif name == "state" and len(shape) == 5:
        if shape[2] % msize == 0:
            spec[2] = "model"
    elif name == "conv" and len(shape) == 4:
        if shape[3] % msize == 0:
            spec[3] = "model"
    return P(*spec)


def cache_shardings(cache_specs: Any, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf.shape, mesh)),
        cache_specs)


def opt_spec(path: tuple, shape: tuple, mesh: Mesh):
    """ZeRO-1: optimizer moments take the param spec (m/v shard with their
    params; the fsdp dim already spreads them over data)."""
    return param_spec(path, shape, mesh, mode="train")


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
