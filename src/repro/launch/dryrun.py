import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below is ordinary code.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import roofline as rl                    # noqa: E402
from repro.configs import (REGISTRY, SHAPES, TrainConfig,    # noqa: E402
                           applicable_shapes, get_config)
from repro.launch import sharding as sh                      # noqa: E402
from repro.launch.mesh import (make_production_mesh,         # noqa: E402
                               mesh_context)
from repro.models import build_model                         # noqa: E402
from repro.train import optimizer as opt_lib                 # noqa: E402
from repro.train.trainer import TrainState, make_train_step  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "dryrun_results")


def _sds_with_shardings(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def _params_specs(model, mesh, mode):
    pshape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    return _sds_with_shardings(pshape, sh.param_shardings(pshape, mesh, mode))


def analysis_config(cfg, shape, depth_units: int):
    """Variant used ONLY for flop/byte/collective accounting.

    XLA's HloCostAnalysis counts while/scan bodies ONCE (verified by
    calibration: an 8-step scan of matmuls reports 1 step's flops), so the
    production scan-over-layers program under-reports by ~L. We compile the
    same cell at depth 1 and depth 2 with single-chunk attention (q/kv
    chunks = seq, so no inner scan remains) and extrapolate linearly:
        f(L) = f(1) + (L - 1) * (f(2) - f(1)).
    Exact because every layer-scan body is shape-identical.
    """
    big = max(shape.seq_len, 1)
    kw = dict(q_chunk=big, kv_chunk=big, scan_unroll=True)
    if cfg.family == "hybrid":
        kw["n_layers"] = cfg.shared_attn_every * depth_units
    else:
        kw["n_layers"] = depth_units
        if cfg.encoder_layers:
            kw["encoder_layers"] = depth_units
    return cfg.scaled(**kw)


def depth_units_of(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    return cfg.n_layers


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override=None, tcfg=None, param_mode=None,
               donate: bool = False):
    """Build (lowered, meta) for one (arch x shape x mesh) cell.

    Keyword knobs drive §Perf hillclimb variants:
      tcfg        — e.g. TrainConfig(microbatch=k) gradient accumulation
      param_mode  — "serve" in a train cell = TP-only params (no FSDP)
      donate      — alias state (train) / KV cache (decode) in-place
    """
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)

    with mesh_context(mesh):
        batch_shape = model.make_input_specs(shape)
        batch = _sds_with_shardings(batch_shape,
                                    sh.batch_shardings(batch_shape, mesh))
        if shape.kind == "train":
            mode = param_mode or "train"
            params = _params_specs(model, mesh, mode)
            opt_shape = jax.eval_shape(opt_lib.init_opt_state, params)
            opt = _sds_with_shardings(
                opt_shape,
                jax.tree_util.tree_map_with_path(
                    lambda p, l: jax.sharding.NamedSharding(
                        mesh, sh.param_spec(p, l.shape, mesh, mode)),
                    opt_shape))
            state = TrainState(params=params, opt=opt)
            step_fn = make_train_step(model, tcfg or TrainConfig())
            lowered = jax.jit(
                step_fn, donate_argnums=(0,) if donate else ()).lower(
                    state, batch)
        elif shape.kind == "prefill":
            params = _params_specs(model, mesh, param_mode or "serve")
            lowered = jax.jit(model.prefill).lower(params, batch)
        else:  # decode
            params = _params_specs(model, mesh, param_mode or "serve")
            # per-device batch over `data`; seq dim of the cache over `model`
            cache_shape = model.init_cache_specs(shape.global_batch,
                                                 shape.seq_len)
            cache = _sds_with_shardings(
                cache_shape, sh.cache_shardings(cache_shape, mesh))
            b_ax = "data" if shape.global_batch % mesh.shape["data"] == 0 \
                else None
            tokens = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(b_ax)))
            lowered = jax.jit(
                model.decode_step,
                donate_argnums=(1,) if donate else ()).lower(
                    params, cache, tokens)
    return lowered, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR, save_hlo: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    cfg = get_config(arch)
    skip = dict(applicable_shapes(cfg)).get(SHAPES[shape_name].name)
    for s, reason in applicable_shapes(cfg):
        if s.name == shape_name and reason is not None:
            rec.update(status="skipped", reason=reason)
            _write(rec, out_dir)
            return rec
    t0 = time.time()
    try:
        lowered, mesh, cfg, shape = lower_cell(arch, shape_name, multi_pod)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            rec["cost_analysis"] = {k: float(v) for k, v in cost.items()
                                    if isinstance(v, (int, float))}
        except Exception as e:
            rec["cost_analysis"] = {"error": str(e)}

        hlo = compiled.as_text()
        coll = rl.parse_collectives(hlo)
        rec["collectives_raw"] = coll.to_json()
        if save_hlo:
            hpath = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.hlo")
            with open(hpath, "w") as f:
                f.write(hlo)
        del compiled, lowered

        # --- accounting compiles (see analysis_config docstring): depth 1 &
        # 2 with single-chunk attention, then linear extrapolation in depth.
        probes = {}
        for u in (1, 2):
            lw, *_ = lower_cell(arch, shape_name, multi_pod,
                                cfg_override=analysis_config(cfg, shape, u))
            cm = lw.compile()
            ca = cm.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            pc = rl.parse_collectives(cm.as_text())
            probes[u] = (float(ca.get("flops", 0.0)),
                         float(ca.get("bytes accessed", 0.0)),
                         pc.moved_bytes, dict(pc.op_bytes), pc.n_ops)
            del cm, lw
        units = depth_units_of(cfg)

        def extrap(i):
            return probes[1][i] + (units - 1) * (probes[2][i] - probes[1][i])

        flops, bytes_acc, coll_moved = extrap(0), extrap(1), extrap(2)
        op_bytes = {
            k: probes[1][3].get(k, 0.0) + (units - 1)
            * (probes[2][3].get(k, 0.0) - probes[1][3].get(k, 0.0))
            for k in set(probes[1][3]) | set(probes[2][3])}
        n_ops = probes[1][4] + (units - 1) * (probes[2][4] - probes[1][4])
        coll_x = rl.CollectiveStats(op_bytes=op_bytes,
                                    moved_bytes=coll_moved, n_ops=n_ops)
        rec["collectives"] = coll_x.to_json()
        rec["probe_depths"] = {str(u): probes[u][:3] for u in probes}
        # roofline table is single-pod (harness contract); the multi-pod
        # pass proves the pod axis shards. ICI bandwidth for the link term.
        n_dev = 512 if multi_pod else 256
        mf = rl.model_flops_for(cfg, shape, rl.active_params(cfg))
        roof = rl.compute_roofline(flops, bytes_acc, coll_x, n_dev, mf,
                                   link_bw=rl.ICI_BW)
        rec["roofline"] = roof.to_json()
    except Exception:
        rec["status"] = "error"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = sorted(REGISTRY) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    args.out, f"{arch}_{shape}_{mesh_name}.json")
                if args.skip_done and os.path.exists(path):
                    with open(path) as f:
                        old = json.load(f)
                    if old.get("status") in ("ok", "skipped"):
                        print(f"[dryrun] {arch} {shape} {mesh_name}: cached",
                              flush=True)
                        continue
                rec = run_cell(arch, shape, mp, args.out, args.save_hlo)
                msg = rec["status"]
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    msg += (f" compile={rec['compile_s']}s"
                            f" bottleneck={r['bottleneck']}"
                            f" terms=({r['compute_s']:.2e},"
                            f"{r['memory_s']:.2e},{r['collective_s']:.2e})s")
                elif rec["status"] == "skipped":
                    msg += f" ({rec['reason']})"
                print(f"[dryrun] {arch} {shape} {mesh_name}: {msg}",
                      flush=True)


if __name__ == "__main__":
    main()
