"""Runtime launch policy: env / XLA-flag / dtype tuning idioms.

The HomebrewNLP-style recipe (SNIPPETS.md): tcmalloc preload, silenced
TF/XLA logging, an explicit ``JAX_DEFAULT_DTYPE_BITS=32`` dtype policy,
and merged (never clobbered) ``XLA_FLAGS``. ``apply()`` setdefaults the
policy into ``os.environ`` and must run **before** jax is imported —
``scripts/launch.sh`` applies the same policy from the shell, which is the
only place the tcmalloc ``LD_PRELOAD`` can happen (a running process
cannot re-preload its allocator; ``apply()`` just reports availability).

Used by ``benchmarks/run.py`` and ``examples/serve_risk_api.py``; both log
the effective environment via ``log()`` so every recorded benchmark is
attributable to a concrete runtime configuration.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Iterable, Optional

TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
)

ENV_DEFAULTS: Dict[str, str] = {
    "TF_CPP_MIN_LOG_LEVEL": "4",               # silence TF/XLA chatter
    "JAX_DEFAULT_DTYPE_BITS": "32",            # f32 policy, no implicit x64
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}

# deployment-specific XLA flags go here (merged into $XLA_FLAGS, existing
# user flags win); empty by default — the CPU container needs none
XLA_FLAG_DEFAULTS: tuple = ()

# large-n scale-out knobs (PR 8): how many data shards the scoring engine
# spreads a batch over (0 = auto: one shard per local device), and the row
# count of one streaming-fit chunk (the working-set bound of fit_stream)
DATA_SHARDS_ENV = "REPRO_DATA_SHARDS"
STREAM_CHUNK_ENV = "REPRO_STREAM_CHUNK"
STREAM_CHUNK_DEFAULT = 65536


def data_shards() -> int:
    """``$REPRO_DATA_SHARDS`` as an int; 0 means auto (per-device)."""
    try:
        return max(int(os.environ.get(DATA_SHARDS_ENV, "0")), 0)
    except ValueError:
        return 0


def stream_chunk() -> int:
    """``$REPRO_STREAM_CHUNK`` rows per streaming-fit chunk (>= 1)."""
    try:
        return max(int(os.environ.get(STREAM_CHUNK_ENV,
                                      str(STREAM_CHUNK_DEFAULT))), 1)
    except ValueError:
        return STREAM_CHUNK_DEFAULT


def find_tcmalloc() -> Optional[str]:
    for p in TCMALLOC_PATHS:
        if os.path.exists(p):
            return p
    return None


def tcmalloc_active() -> bool:
    return "tcmalloc" in os.environ.get("LD_PRELOAD", "")


def apply(extra_env: Optional[Dict[str, str]] = None,
          xla_flags: Iterable[str] = XLA_FLAG_DEFAULTS) -> Dict[str, str]:
    """Setdefault the runtime policy into the environment.

    Returns the keys actually set (existing values are never overridden).
    Call before importing jax; a late call is detected and flagged in the
    returned dict under ``"_late"`` since env-derived config (dtype bits,
    XLA flags) is read at import/backend-init time.
    """
    applied: Dict[str, str] = {}
    for k, v in {**ENV_DEFAULTS, **(extra_env or {})}.items():
        if k not in os.environ:
            os.environ[k] = v
            applied[k] = v
    merged = [f for f in xla_flags
              if f not in os.environ.get("XLA_FLAGS", "")]
    if merged:
        flags = (os.environ.get("XLA_FLAGS", "") + " " + " ".join(merged))
        os.environ["XLA_FLAGS"] = flags.strip()
        applied["XLA_FLAGS"] = os.environ["XLA_FLAGS"]
    if applied and "jax" in sys.modules:
        applied["_late"] = "jax already imported; defaults may not apply"
    return applied


def describe() -> Dict[str, object]:
    """The effective runtime environment (imports jax lazily)."""
    import jax

    from ..models import compat as models_compat

    tc = find_tcmalloc()
    return {
        "mesh_probe": models_compat.MESH_PROBE,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "ld_preload": os.environ.get("LD_PRELOAD", ""),
        "tcmalloc": ("active" if tcmalloc_active()
                     else f"available:{tc}" if tc else "absent"),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "env": {k: os.environ.get(k, "") for k in ENV_DEFAULTS},
        "tune_cache": os.environ.get("REPRO_TUNE_CACHE", "(default)"),
        "data_shards": data_shards() or "(auto)",
        "stream_chunk": stream_chunk(),
    }


def log(prefix: str = "[runtime]") -> Dict[str, object]:
    """Print and return the effective environment, one line per field.

    Also emits a ``runtime.env`` snapshot event to the JSONL sink (when
    ``$REPRO_EVENTS_FILE`` is on), so every recorded trace/benchmark
    stream opens with the runtime configuration that produced it.
    """
    d = describe()
    for k, v in d.items():
        print(f"{prefix} {k}={v}", flush=True)
    if not tcmalloc_active() and find_tcmalloc():
        print(f"{prefix} note: tcmalloc present but not preloaded — "
              "launch via scripts/launch.sh to enable it", flush=True)
    if d["mesh_probe"] != "abstract":
        # loud on purpose: the last silent API drift here
        # (jax.sharding.get_abstract_mesh missing on 0.4.37) took out all
        # 41 model-zoo tests — surface the compat seam in every snapshot
        from ..models import compat as models_compat

        print(f"{prefix} WARNING: jax {d['jax_version']} has no public "
              "mesh probe; pspec.constrain is on the thread-resources "
              "physical-mesh fallback (supported floor: jax >= "
              f"{models_compat.JAX_FLOOR})", flush=True)
    from ..obs import events as obs_events

    obs_events.emit("runtime.env", **{k: v for k, v in d.items()})
    return d
