"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The multi-pod mesh's leading ``pod`` axis is pure
data parallelism: the only cross-pod traffic in a train step is the gradient
all-reduce, which is what the (slower) DCN between pods can sustain.

Also the jax-version compat seam for SPMD entry points: ``shard_map``
moved from ``jax.experimental.shard_map`` into the top-level namespace and
``axis_types`` only exists on newer ``jax.make_mesh`` — every sharded
caller in the repo (core/distributed.py, serving/engine.py) goes through
``shard_map_compat`` / ``_make_mesh`` instead of touching jax directly.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_compat(f, mesh, in_specs, out_specs, check_rep: bool = False):
    """``shard_map`` across jax versions.

    ``check_rep=False`` by default: the replication checker has no rule for
    ``pallas_call`` (the serving curve kernel runs per-shard), and newer jax
    renamed the knob — fall back to calling without it when unsupported.
    """
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions.

    Newer jax wants ``jax.set_mesh(mesh)`` (or ``jax.sharding.use_mesh``);
    on 0.4.x neither exists and the ``Mesh`` object is its own context
    manager (``with mesh:``), which populates the thread-resources
    physical mesh that ``models/compat.get_abstract_mesh`` falls back to.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is None:
        setter = getattr(jax.sharding, "use_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types when this jax version has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (tests / examples)."""
    return _make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_shards: int):
    """1-D ``data`` mesh over ``n_shards`` local devices — the scoring
    engine's batch-parallel mesh (requests shard over rows, model state is
    replicated). ``n_shards`` must not exceed ``jax.local_device_count()``."""
    return _make_mesh((int(n_shards),), ("data",))


def dp_axes(mesh) -> tuple:
    """Axes the batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh) -> str:
    """Axis weights/optimizer state are FSDP-sharded over (in-pod only —
    cross-pod weight gathering over DCN would dominate the step)."""
    return "data"
