"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The multi-pod mesh's leading ``pod`` axis is pure
data parallelism: the only cross-pod traffic in a train step is the gradient
all-reduce, which is what the (slower) DCN between pods can sustain.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh for CPU smoke paths (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    """Axes the batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh) -> str:
    """Axis weights/optimizer state are FSDP-sharded over (in-pod only —
    cross-pod weight gathering over DCN would dominate the step)."""
    return "data"
