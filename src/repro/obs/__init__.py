"""Unified telemetry for the FastSurvival stack — dependency-free.

Three layers (stdlib + the jax/numpy already in the tree; nothing else):

``metrics.py``
    Counters / gauges / fixed-bucket histograms in a thread-safe
    ``Registry`` (process-global ``REGISTRY`` default, injectable
    instances for tests), a Prometheus-text exporter served by
    ``serve_metrics()``, and a JSON-able ``snapshot()`` embedded into
    ``BENCH_*.json`` by ``benchmarks/run.py --json``.

``events.py`` / ``trace.py``
    A JSONL event sink (``$REPRO_EVENTS_FILE``) and nested timed spans
    with per-trace ids (``$REPRO_TRACE_FILE``), summarized into the
    per-stage latency-breakdown table by ``repro.analysis.report``.

``solver.py``
    ``TelemetryCallback`` — per-iteration (objective, grad norm, step
    norm, active set) records via ``jax.debug.callback``, plus the
    ``solver_monotonicity_violations_total`` counter that turns the
    paper's loss-decrease guarantee into a monitored invariant. Threaded
    through ``core/solvers.py`` and ``core/beam.py`` as a static jit
    argument: ``None`` (the default) stages nothing.

``profile.py``
    ``maybe_profile(name)`` — ``jax.profiler`` capture under
    ``$REPRO_PROFILE_DIR``, no-op otherwise.

Instrumented call sites: ``serving/service.py`` (queue/batch/dispatch
spans, queue-depth gauge, shed/timeout counters), ``serving/engine.py``
(compile events, bucket-size histogram), ``kernels/ops.py`` (dispatch
counters with tuned/default tags), ``kernels/autotune.py`` (profiled
sweeps), ``launch/runtime.py`` (env snapshot event).

Everything is overhead-free when off: disabled sinks are one ``None``
check, disabled solver telemetry traces the pre-telemetry graph, and
metric updates on always-on counters are single locked dict writes.
"""
from . import events, metrics, profile, trace  # noqa: F401
from .metrics import REGISTRY, Registry, serve_metrics  # noqa: F401
from .solver import TelemetryCallback, emit_iter  # noqa: F401
from .trace import span  # noqa: F401
