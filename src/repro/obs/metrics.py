"""Process-local metrics: counters, gauges, histograms.

Dependency-free (stdlib, plus numpy in the vectorized ``observe_many``
batch path). A ``Registry`` owns named metrics; metric updates are
thread-safe (one lock per metric family) and cheap enough for the
serving hot path: single observations bucket via C-speed ``bisect``, and
the serving loop records a whole micro-batch of latencies under one lock
with ``observe_many``. Two export surfaces:

  * ``Registry.to_prometheus()`` — the Prometheus text exposition format
    (``# HELP`` / ``# TYPE`` headers, ``_bucket``/``_sum``/``_count``
    histogram series with cumulative ``le`` labels), servable via
    ``serve_metrics()``'s stdlib HTTP endpoint;
  * ``Registry.snapshot()`` — a JSON-able dict, embedded into the
    ``BENCH_*.json`` trajectory artifacts by ``benchmarks/run.py --json``
    and validated by its smoke gate.

``REGISTRY`` is the process-global default; subsystems accept an
injectable registry for test isolation but fall back to it.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

# serving latencies land in 100us..10s; seconds, Prometheus-style ladder
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# power-of-two ladder for batch/bucket-size histograms
POW2_BUCKETS: Tuple[float, ...] = tuple(float(1 << i) for i in range(13))

LabelValues = Tuple[str, ...]


def _label_key(label_names: Sequence[str], labels: Dict[str, str]) -> LabelValues:
    if not labels:                     # hot-path: labelless metric
        if label_names:
            raise ValueError(f"expected labels {tuple(label_names)}, got ()")
        return ()
    if set(labels) != set(label_names):
        raise ValueError(
            f"expected labels {tuple(label_names)}, got {tuple(labels)}")
    return tuple(str(labels[k]) for k in label_names)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, label_names: Sequence[str]):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonically increasing count, optionally labelled."""

    kind = "counter"

    def __init__(self, name, help="", label_names=()):
        super().__init__(name, help, label_names)
        self._values: Dict[LabelValues, float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _label_key(self.label_names, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: str) -> float:
        key = _label_key(self.label_names, labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())

    def _series(self):
        with self._lock:
            return dict(self._values)


class Gauge(_Metric):
    """A value that can go up and down (queue depth, active threads)."""

    kind = "gauge"

    def __init__(self, name, help="", label_names=()):
        super().__init__(name, help, label_names)
        self._values: Dict[LabelValues, float] = {}
        self._fn = None

    def set_fn(self, fn) -> None:
        """Labelless callback gauge: ``fn()`` is evaluated at
        export/snapshot time, so the instrumented hot path pays nothing
        (the serving queue-depth idiom). Overrides stored values."""
        if self.label_names:
            raise ValueError("callback gauges must be labelless")
        self._fn = fn

    def set(self, value: float, **labels: str) -> None:
        key = _label_key(self.label_names, labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = _label_key(self.label_names, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def set_state(self, state: str, states: Sequence[str]) -> None:
        """One-hot enum gauge (the Prometheus state-set idiom): the
        current state's series reads 1, every other known state 0 — so a
        scrape always sees exactly one active state and dashboards can
        alert on e.g. ``service_health_state{state="down"} == 1``.
        Requires exactly one label naming the state dimension."""
        if len(self.label_names) != 1:
            raise ValueError("state gauges need exactly one label")
        name = self.label_names[0]
        with self._lock:
            for s in states:
                self._values[(str(s),)] = 1.0 if s == state else 0.0

    def value(self, **labels: str) -> float:
        if self._fn is not None and not labels:
            return float(self._fn())
        key = _label_key(self.label_names, labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def _series(self):
        if self._fn is not None:
            try:
                return {(): float(self._fn())}
            except Exception:
                return {}
        with self._lock:
            return dict(self._values)


class Histogram(_Metric):
    """Fixed-boundary histogram (per-bucket counts + sum + count).

    Boundaries are upper bounds of non-cumulative bins; the export adds
    the implicit ``+Inf`` bucket and emits cumulative counts as
    Prometheus requires.
    """

    kind = "histogram"

    def __init__(self, name, help="", label_names=(),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, label_names)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = b
        self._bucket_arr = np.asarray(b)       # searchsorted fast path
        self._counts: Dict[LabelValues, list] = {}
        self._sum: Dict[LabelValues, float] = {}
        self._n: Dict[LabelValues, int] = {}

    def _bins(self, key):
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = np.zeros(len(self.buckets) + 1,
                                                  dtype=np.int64)
            self._sum[key] = 0.0
            self._n[key] = 0
        return counts

    def observe(self, value: float, **labels: str) -> None:
        key = _label_key(self.label_names, labels)
        v = float(value)
        # bisect_left: index of the first bucket with v <= ub, or the
        # implicit +Inf bin at len(buckets) — C-speed, hot-path safe
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._bins(key)[i] += 1
            self._sum[key] += v
            self._n[key] += 1

    def observe_many(self, values: Iterable[float], **labels: str) -> None:
        """Record a batch of observations under one lock acquisition —
        the serving loop's per-micro-batch path. Vectorized (numpy
        searchsorted + bincount), so cost is ~flat in batch size."""
        key = _label_key(self.label_names, labels)
        vs = np.asarray(values if isinstance(values, np.ndarray)
                        else list(values), dtype=float)
        if vs.size == 0:
            return
        binc = np.bincount(np.searchsorted(self._bucket_arr, vs,
                                           side="left"),
                           minlength=len(self.buckets) + 1)
        total, n = float(vs.sum()), int(vs.size)
        with self._lock:
            counts = self._bins(key)
            counts += binc
            self._sum[key] += total
            self._n[key] += n

    def count(self, **labels: str) -> int:
        key = _label_key(self.label_names, labels)
        with self._lock:
            return self._n.get(key, 0)

    def sum(self, **labels: str) -> float:
        key = _label_key(self.label_names, labels)
        with self._lock:
            return self._sum.get(key, 0.0)

    def _series(self):
        with self._lock:
            return {k: {"counts": [int(c) for c in cs],
                        "sum": self._sum[k], "count": self._n[k]}
                    for k, cs in self._counts.items()}


class Registry:
    """Named metric families; get-or-create, never duplicate."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name, help, label_names, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, label_names, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls) or m.label_names != tuple(label_names):
                raise ValueError(
                    f"metric {name!r} re-registered with a different "
                    f"type/labels")
            return m

    def counter(self, name: str, help: str = "",
                label_names: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, label_names)

    def histogram(self, name: str, help: str = "",
                  label_names: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, label_names, buckets=buckets)

    def metrics(self) -> Iterable[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def reset(self) -> None:
        """Drop all metric families (test isolation)."""
        with self._lock:
            self._metrics.clear()

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able state of every family: the BENCH_*.json embedding."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self.metrics():
            series = m._series()
            if isinstance(m, Histogram):
                out["histograms"][m.name] = {
                    "buckets": list(m.buckets),
                    "series": {_fmt_labels(m.label_names, k) or "": v
                               for k, v in series.items()},
                }
            else:
                group = "counters" if isinstance(m, Counter) else "gauges"
                out[group][m.name] = {
                    _fmt_labels(m.label_names, k) or "": v
                    for k, v in series.items()}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for m in sorted(self.metrics(), key=lambda m: m.name):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            series = m._series()
            if isinstance(m, Histogram):
                for key, s in sorted(series.items()):
                    cum = 0
                    for ub, c in zip(m.buckets + (float("inf"),),
                                     s["counts"]):
                        cum += c
                        le = "+Inf" if ub == float("inf") else _fmt_num(ub)
                        lbl = _fmt_labels(m.label_names + ("le",),
                                          key + (le,))
                        lines.append(f"{m.name}_bucket{{{lbl}}} {cum}")
                    base = _fmt_labels(m.label_names, key)
                    brace = f"{{{base}}}" if base else ""
                    lines.append(f"{m.name}_sum{brace} {_fmt_num(s['sum'])}")
                    lines.append(f"{m.name}_count{brace} {s['count']}")
            else:
                for key, v in sorted(series.items()):
                    base = _fmt_labels(m.label_names, key)
                    brace = f"{{{base}}}" if base else ""
                    lines.append(f"{m.name}{brace} {_fmt_num(v)}")
        return "\n".join(lines) + "\n"


def _fmt_labels(names: Sequence[str], values: LabelValues) -> str:
    return ",".join(f'{n}="{v}"' for n, v in zip(names, values))


def _fmt_num(v: float) -> str:
    return str(int(v)) if float(v) == int(v) else repr(float(v))


# the process-global default registry
REGISTRY = Registry()


def serve_metrics(port: int = 0, registry: Optional[Registry] = None,
                  host: str = "127.0.0.1"):
    """Serve ``GET /metrics`` (Prometheus text) on a daemon thread.

    Returns the ``HTTPServer``; ``server.server_address[1]`` is the bound
    port (useful with ``port=0``), ``server.shutdown()`` stops it.
    """
    from http.server import BaseHTTPRequestHandler, HTTPServer

    reg = registry if registry is not None else REGISTRY

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            body = reg.to_prometheus().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):   # keep stdout clean
            pass

    server = HTTPServer((host, port), Handler)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    return server
