"""JSONL event sink — the durable half of the telemetry subsystem.

One event per line: ``{"ts": <unix seconds>, "kind": "<dotted.name>",
...fields}``. Spans (``trace.py``), solver iterations (``solver.py``),
engine compile events, and the runtime env snapshot all flow through
here, so a single file replays a run end to end.

Disabled by default and free when disabled: ``emit()`` is a ``None``
check. Enable by pointing ``$REPRO_EVENTS_FILE`` at a path before import
(or any time, via ``configure(path)``); ``configure(None)`` turns it
back off. Writes are line-buffered and serialized under a lock, so
concurrent emitters (the serving threads) never interleave partial
lines.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import IO, Optional

ENV_VAR = "REPRO_EVENTS_FILE"


class JsonlSink:
    """Append-only, thread-safe JSONL writer."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f: IO[str] = open(path, "a")
        self._lock = threading.Lock()

    def emit(self, kind: str, **fields) -> None:
        rec = {"ts": time.time(), "kind": kind}
        rec.update(fields)
        line = json.dumps(rec, default=_jsonable)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


def _jsonable(o):
    """Last-resort coercion so numpy scalars etc. never kill an emit."""
    try:
        return float(o)
    except (TypeError, ValueError):
        return str(o)


_LOCK = threading.Lock()
_SINK: Optional[JsonlSink] = None
_ENV_CHECKED = False


def configure(path: Optional[str]) -> Optional[JsonlSink]:
    """Point the global sink at ``path`` (None disables)."""
    global _SINK, _ENV_CHECKED
    with _LOCK:
        if _SINK is not None:
            _SINK.close()
        _SINK = JsonlSink(path) if path else None
        _ENV_CHECKED = True   # explicit configure wins over the env var
        return _SINK


def get_sink() -> Optional[JsonlSink]:
    """The global sink, lazily picking up ``$REPRO_EVENTS_FILE`` once."""
    global _SINK, _ENV_CHECKED
    if _SINK is None and not _ENV_CHECKED:
        with _LOCK:
            if _SINK is None and not _ENV_CHECKED:
                path = os.environ.get(ENV_VAR)
                if path:
                    _SINK = JsonlSink(path)
                _ENV_CHECKED = True
    return _SINK


def emit(kind: str, **fields) -> None:
    """Emit one event to the global sink; no-op when disabled."""
    sink = get_sink()
    if sink is not None:
        sink.emit(kind, **fields)


def enabled() -> bool:
    return get_sink() is not None


def read_jsonl(path: str):
    """Parse a JSONL file, skipping blank/corrupt lines (analysis helper)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError:
                continue
    return out
