"""Timed, nested tracing spans with per-trace ids, exported as JSONL.

    with trace.span("engine.score", batch=32):
        ...

Spans nest via a thread-local stack: the first span on a thread roots a
new trace (fresh ``trace_id``); children inherit it and record their
parent's ``span_id``, so the JSONL stream reconstructs the tree. A root
can also be opened with an explicit ``trace_id`` (the serving loop tags
every batch's trace onto its responses).

Export goes to the span sink: ``$REPRO_TRACE_FILE`` when set, else the
shared event sink (``events.py``), else nowhere. Disabled tracing costs
one ``None`` check per ``span()`` call — the serving hot path stays
unperturbed when observability is off (<2% is the budgeted regression;
a no-op singleton context manager keeps it far below that).

``repro.analysis.report.latency_breakdown_table`` summarizes a span
JSONL file into per-stage latency totals/percentiles.
"""
from __future__ import annotations

import os
import threading
import time
import uuid
from typing import Optional

from . import events

ENV_VAR = "REPRO_TRACE_FILE"

_LOCAL = threading.local()
_LOCK = threading.Lock()
_SINK: Optional[events.JsonlSink] = None
_SINK_RESOLVED = False


def configure(path: Optional[str]) -> None:
    """Send spans to ``path`` (None: fall back to the event sink)."""
    global _SINK, _SINK_RESOLVED
    with _LOCK:
        if _SINK is not None:
            _SINK.close()
        _SINK = events.JsonlSink(path) if path else None
        _SINK_RESOLVED = path is not None


def _sink() -> Optional[events.JsonlSink]:
    global _SINK, _SINK_RESOLVED
    if not _SINK_RESOLVED:
        with _LOCK:
            if not _SINK_RESOLVED:
                path = os.environ.get(ENV_VAR)
                if path:
                    _SINK = events.JsonlSink(path)
                _SINK_RESOLVED = True
    if _SINK is not None:
        return _SINK
    return events.get_sink()


def enabled() -> bool:
    return _sink() is not None


def _stack() -> list:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = _LOCAL.stack = []
    return st


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


def current_trace_id() -> Optional[str]:
    st = _stack()
    return st[-1].trace_id if st else None


class _NoopSpan:
    """Shared do-nothing span for the disabled path."""

    trace_id = None
    span_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _NoopSpan()


class Span:
    __slots__ = ("name", "attrs", "trace_id", "span_id", "parent_id",
                 "_t0", "_sink")

    def __init__(self, name: str, attrs: dict, sink: events.JsonlSink,
                 trace_id: Optional[str]):
        self.name = name
        self.attrs = attrs
        self._sink = sink
        st = _stack()
        parent = st[-1] if st else None
        self.parent_id = parent.span_id if parent else None
        self.trace_id = (trace_id or (parent.trace_id if parent else None)
                         or new_trace_id())
        self.span_id = uuid.uuid4().hex[:16]
        self._t0 = 0.0

    def set(self, **attrs):
        """Attach attributes mid-span (recorded at exit)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        _stack().append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter() - self._t0
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        rec = {"name": self.name, "trace_id": self.trace_id,
               "span_id": self.span_id, "parent_id": self.parent_id,
               "dur_s": dur, "thread": threading.current_thread().name}
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        if self.attrs:
            rec["attrs"] = self.attrs
        self._sink.emit("span", **rec)
        return False


def span(name: str, trace_id: Optional[str] = None, **attrs):
    """Open a timed span; returns a no-op when tracing is disabled."""
    sink = _sink()
    if sink is None:
        return _NOOP
    return Span(name, attrs, sink, trace_id)


def emit_span(name: str, dur_s: float, trace_id: Optional[str] = None,
              parent_id: Optional[str] = None, **attrs) -> None:
    """Record an already-elapsed interval as a span (no-op when disabled).

    For durations measured outside a ``with`` block — e.g. a request's
    queue wait, which has already passed by the time the batch forms.
    Inherits the enclosing span's trace/parent when not given explicitly.
    """
    sink = _sink()
    if sink is None:
        return
    st = _stack()
    parent = st[-1] if st else None
    rec = {"name": name,
           "trace_id": (trace_id or (parent.trace_id if parent else None)
                        or new_trace_id()),
           "span_id": uuid.uuid4().hex[:16],
           "parent_id": parent_id or (parent.span_id if parent else None),
           "dur_s": float(dur_s),
           "thread": threading.current_thread().name}
    if attrs:
        rec["attrs"] = attrs
    sink.emit("span", **rec)
