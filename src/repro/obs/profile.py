"""Optional ``jax.profiler`` capture, gated by ``$REPRO_PROFILE_DIR``.

    with profile.maybe_profile("autotune/cox_batch"):
        ... timed kernel calls ...

When the env var is unset this is a no-op (one dict lookup). When set,
the block runs under ``jax.profiler.trace`` writing a TensorBoard-
loadable trace into ``$REPRO_PROFILE_DIR/<name>``; a ``profile.capture``
event records where it landed. Profiler failures (unsupported backend,
concurrent capture) degrade to a warning event, never an exception — a
profiling flag must not take down a tuning run.
"""
from __future__ import annotations

import contextlib
import os
import re

from . import events

ENV_VAR = "REPRO_PROFILE_DIR"


def profile_dir():
    return os.environ.get(ENV_VAR) or None


def _safe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\-/]", "_", name).strip("/")


@contextlib.contextmanager
def maybe_profile(name: str):
    """Profile the block iff ``$REPRO_PROFILE_DIR`` is set."""
    base = profile_dir()
    if not base:
        yield
        return
    target = os.path.join(base, _safe(name))
    try:
        import jax

        os.makedirs(target, exist_ok=True)
        ctx = jax.profiler.trace(target)
    except Exception as e:   # profiler unavailable: degrade, don't die
        events.emit("profile.error", name=name, error=repr(e))
        yield
        return
    try:
        with ctx:
            yield
    finally:
        events.emit("profile.capture", name=name, dir=target)
