"""Solver convergence telemetry — the paper's guarantee, monitored.

FastSurvival's surrogate solvers promise monotone objective decrease
(Prop. 3.2's majorization); the test suite asserts it, but production
fits never observed it. ``TelemetryCallback`` turns the guarantee into a
monitored invariant: thread an instance through ``core/solvers.py`` /
``core/beam.py`` and every outer iteration records (objective, gradient
norm, step norm, active-set size) host-side via ``jax.debug.callback``,
checks monotonicity against the neighboring iterations, and counts any
increase beyond ``tol`` in the ``solver_monotonicity_violations_total``
metric (and per-iteration ``solver.iter`` events when the JSONL sink is
on).

Zero-cost when off: ``telemetry`` is a *static* jit argument, so
``telemetry=None`` traces the exact pre-telemetry graph — no callback op,
no extra gradient evaluations. Reuse one instance across calls of the
same solver signature to avoid retraces (each new instance is a fresh
static value).

Callbacks are unordered (`lax.while_loop` forbids ordered effects), so
records carry their iteration index and the monotonicity check fires
when both sides of an adjacent pair have arrived — each pair is checked
exactly once regardless of arrival order.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

from . import events, metrics


class TelemetryCallback:
    """Host-side per-iteration solver recorder (hashable; jit-static)."""

    def __init__(self, solver: str = "solver", tol: float = 1e-6,
                 registry: Optional[metrics.Registry] = None):
        self.solver = solver
        self.tol = float(tol)
        reg = registry if registry is not None else metrics.REGISTRY
        self._iters = reg.counter(
            "solver_iterations_total",
            "outer solver iterations recorded", ("solver",))
        self._violations = reg.counter(
            "solver_monotonicity_violations_total",
            "objective increases beyond tol between consecutive iterations",
            ("solver",))
        self._lock = threading.Lock()
        self._records: Dict[int, dict] = {}

    # -- device -> host ----------------------------------------------------

    def _cb(self, it, objective, grad_norm, step_norm, active_set) -> None:
        rec = {"iter": int(it), "objective": float(objective),
               "grad_norm": float(grad_norm),
               "step_norm": float(step_norm),
               "active_set": int(active_set)}
        new_violations = 0
        with self._lock:
            self._records[rec["iter"]] = rec
            # adjacent pairs (it-1, it) and (it, it+1): each pair fires
            # exactly once, when the later-arriving member lands
            for lo in (rec["iter"] - 1, rec["iter"]):
                a = self._records.get(lo)
                b = self._records.get(lo + 1)
                if a is None or b is None or (a is not rec and b is not rec):
                    continue
                if b["objective"] > a["objective"] + self.tol:
                    new_violations += 1
        self._iters.inc(solver=self.solver)
        if new_violations:
            self._violations.inc(new_violations, solver=self.solver)
        events.emit("solver.iter", solver=self.solver, **rec)

    # -- host-side recording (beam search outer loop etc.) -----------------

    def record_event(self, kind: str, **fields) -> None:
        events.emit(kind, solver=self.solver, **fields)

    # -- inspection --------------------------------------------------------

    @property
    def records(self) -> List[dict]:
        with self._lock:
            return [self._records[i] for i in sorted(self._records)]

    @property
    def objectives(self) -> np.ndarray:
        return np.asarray([r["objective"] for r in self.records])

    @property
    def violations(self) -> int:
        return int(self._violations.value(solver=self.solver))

    @property
    def iterations(self) -> int:
        with self._lock:
            return len(self._records)

    def reset(self) -> None:
        """Drop recorded iterations (counters are cumulative and stay)."""
        with self._lock:
            self._records.clear()


def emit_iter(telemetry: Optional[TelemetryCallback], it, objective,
              grad_norm, step_norm, active_set) -> None:
    """Insert a host callback recording one outer iteration.

    Call from *traced* solver code; a ``None`` telemetry is free (no op is
    staged). All five value arguments must be jax scalars.
    """
    if telemetry is None:
        return
    import jax

    jax.debug.callback(telemetry._cb, it, objective, grad_norm, step_norm,
                       active_set)
