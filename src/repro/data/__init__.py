from .synthetic import (  # noqa: F401
    SyntheticSpec,
    make_correlated_survival,
    make_attrition_like,
)
