"""Deterministic, restart-safe data pipeline.

Batches are generated per step from a seed derived via
fault_tolerance.DataSkipper, so a restarted run reproduces the exact
stream. `put_batch` shards host batches onto the mesh (batch dim over the
dp axes). For the examples we use synthetic token streams / survival-
labelled sequence tasks (no external corpora offline).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..train.fault_tolerance import DataSkipper


class TokenTaskStream:
    """Synthetic autoregressive task: integer sequences with learnable
    structure (a noisy modular-progression) — loss decreases measurably
    within a few hundred steps on a ~100M model."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab = vocab
        self.seq = seq_len
        self.batch = batch
        self.skipper = DataSkipper(seed)

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.skipper.seed_for_step(step))
        start = rng.integers(0, self.vocab, size=(self.batch, 1))
        stride = rng.integers(0, 4, size=(self.batch, 1))
        pos = np.arange(self.seq + 1)[None, :]
        toks = (start + stride * pos) % self.vocab
        noise = rng.integers(0, self.vocab, size=toks.shape)
        mask = rng.random(toks.shape) < 0.02
        toks = np.where(mask, noise, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SurvivalTextStream:
    """Synthetic deep-survival task: token sequences whose (hidden) hazard
    depends on the frequency of a few marker tokens — the backbone must
    learn to count them; the CPH head turns that into risk."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 n_markers: int = 4):
        self.vocab = vocab
        self.seq = seq_len
        self.batch = batch
        self.markers = np.arange(1, 1 + n_markers)
        self.weights = np.linspace(1.0, 2.0, n_markers)
        self.skipper = DataSkipper(seed + 77)

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.skipper.seed_for_step(step))
        toks = rng.integers(0, self.vocab, size=(self.batch, self.seq))
        # plant markers with per-sample intensity
        intensity = rng.random((self.batch, 1)) * 0.2
        plant = rng.random(toks.shape) < intensity
        which = rng.integers(0, len(self.markers), size=toks.shape)
        toks = np.where(plant, self.markers[which], toks).astype(np.int32)
        counts = np.stack([(toks == m).mean(axis=1) for m in self.markers],
                          axis=1)
        risk = counts @ self.weights * 40.0 - 2.0
        v = rng.uniform(1e-9, 1.0, size=self.batch)
        t_event = (-np.log(v) / np.exp(np.clip(risk, -20, 20))) ** 0.3
        c = rng.uniform(0, np.quantile(t_event, 0.85), size=self.batch)
        event = (t_event <= c).astype(np.float32)
        t_obs = np.minimum(t_event, c).astype(np.float32)
        return {"tokens": toks, "time": t_obs, "event": event}


def put_batch(batch: Dict[str, np.ndarray], mesh) -> Dict[str, jax.Array]:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def shard(k, v):
        spec = [None] * v.ndim
        if v.ndim:
            spec[0] = dp
        return jax.device_put(v, NamedSharding(mesh, P(*spec)))

    return {k: shard(k, v) for k, v in batch.items()}
