"""Synthetic survival data generators.

``make_correlated_survival`` follows Appendix C of FastSurvival exactly:
  x_i ~ N(0, Sigma),  Sigma_jl = rho^|j-l|
  beta*_j = 1 if (j+1) mod (p/k) == 0 else 0         (k-sparse)
  t_i = (-log V_i / exp(x_i beta*))^s,  V_i ~ U(0,1), s = 0.1
  C_i ~ U(0,1);  delta_i = 1[t_i > C_i] ... observed t_i = min(t_i, C_i)

(The paper's Eq. 30 has the indicator as written; the conventional
definition is delta=1 when the event is observed, i.e. t_i <= C_i. We use
the conventional one and note the discrepancy — with the paper's literal
indicator, "events" would be exactly the censored samples, and none of the
reported metrics would be computable.)

``make_attrition_like`` mimics the Employee-Attrition preprocessing: a few
latent drivers, continuous columns binarized at many quantile thresholds
-> large blocks of highly correlated one-hot features.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    n: int = 1200
    p: int = 1200
    k: int = 15
    rho: float = 0.9
    s: float = 0.1
    censor_scale: float = 1.0
    seed: int = 0


def _ar1_sample(rng: np.random.Generator, n: int, p: int,
                rho: float) -> np.ndarray:
    """Sample N(0, Sigma) with Sigma_jl = rho^|j-l| in O(np) via the AR(1)
    representation x_j = rho x_{j-1} + sqrt(1-rho^2) eps_j (avoids the
    O(p^3) Cholesky of the paper's direct construction)."""
    eps = rng.standard_normal((n, p))
    x = np.empty((n, p), dtype=np.float64)
    x[:, 0] = eps[:, 0]
    c = np.sqrt(1.0 - rho * rho)
    for j in range(1, p):
        x[:, j] = rho * x[:, j - 1] + c * eps[:, j]
    return x


def make_correlated_survival(
    spec: SyntheticSpec,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (X, t, delta, beta_star) per Appendix C."""
    rng = np.random.default_rng(spec.seed)
    x = _ar1_sample(rng, spec.n, spec.p, spec.rho)
    beta_star = np.zeros(spec.p)
    stride = max(spec.p // spec.k, 1)
    idx = np.arange(1, spec.p + 1)
    beta_star[(idx % stride == 0)] = 1.0
    # cap at k nonzeros (paper's rule can produce a final partial stride)
    nz = np.flatnonzero(beta_star)[: spec.k]
    beta_star = np.zeros(spec.p)
    beta_star[nz] = 1.0

    risk = x @ beta_star
    risk = np.clip(risk, -30.0, 30.0)
    v = rng.uniform(1e-12, 1.0, size=spec.n)
    t_event = (-np.log(v) / np.exp(risk)) ** spec.s
    c = rng.uniform(0.0, spec.censor_scale, size=spec.n)
    delta = (t_event <= c).astype(np.float64)
    t_obs = np.minimum(t_event, c)
    return x.astype(np.float32), t_obs.astype(np.float32), \
        delta.astype(np.float32), beta_star.astype(np.float32)


def make_attrition_like(
    n: int = 2000, n_cont: int = 6, thresholds: int = 40, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Continuous drivers -> quantile-binarized one-hot blocks (highly
    correlated), Weibull-ish attrition times driven by two of the columns."""
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((n, n_cont))
    cols = []
    for j in range(n_cont):
        qs = np.quantile(z[:, j], np.linspace(0.05, 0.95, thresholds))
        cols.append((z[:, j][:, None] >= qs[None, :]).astype(np.float64))
    x = np.concatenate(cols, axis=1)
    risk = 1.2 * z[:, 0] - 0.8 * z[:, 1] + 0.5 * z[:, 2]
    risk = np.clip(risk, -30.0, 30.0)
    v = rng.uniform(1e-12, 1.0, size=n)
    t_event = (-np.log(v) / np.exp(risk)) ** 0.4
    c = rng.uniform(0.0, np.quantile(t_event, 0.8), size=n)
    delta = (t_event <= c).astype(np.float64)
    t_obs = np.minimum(t_event, c)
    return x.astype(np.float32), t_obs.astype(np.float32), \
        delta.astype(np.float32)


def make_tied_survival(n: int = 200, p: int = 8, n_times: int = 20,
                       seed: int = 0):
    """Small dataset with heavy ties (times drawn from a small grid) for
    exercising the Breslow tie handling in tests."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p))
    beta = rng.standard_normal(p) * 0.5
    risk = np.clip(x @ beta, -30, 30)
    v = rng.uniform(1e-12, 1.0, size=n)
    t = (-np.log(v) / np.exp(risk)) ** 0.5
    t = np.ceil(t * n_times) / n_times  # grid -> ties
    delta = (rng.uniform(size=n) < 0.7).astype(np.float64)
    return x.astype(np.float32), t.astype(np.float32), delta.astype(np.float32)
