"""Fault-tolerant checkpointing (no orbax offline).

Layout: <dir>/step_<N>/  with one .npy per leaf + manifest.json
(tree structure, shapes, dtypes, step). Writes go to a tmp dir that is
atomically renamed, so a crash mid-save can never corrupt the latest
checkpoint. ``save_async`` runs the device_get + write on a worker thread,
overlapping I/O with the next training steps (double-buffered: at most one
in-flight save). Restore accepts a *different* mesh/sharding than the save
used — leaves are stored unsharded, so elastic resizes (e.g. a data axis
shrunk after losing a pod) just re-device_put with the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "###"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Blocking save; returns the checkpoint path."""
    leaves, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{abs(hash(key)) % 10**12}_{len(manifest['leaves'])}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """At most one in-flight save; ``wait()`` before shutdown."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        # device_get on the main thread (jax arrays are not thread-safe to
        # donate), then write on the worker
        leaves, _ = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}

        def _write():
            save(self.ckpt_dir, step, host, keep=self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings for resharded (elastic) restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target)
    shard_leaves = _flatten(shardings)[0] if shardings is not None else None
    out = []
    for key in leaves:
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[key])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
