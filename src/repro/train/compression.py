"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 block quantization with error feedback: each gradient leaf is scaled
per 256-element block to int8, the quantization error is carried in a
residual buffer and added back next step (error feedback keeps SGD/Adam
convergence — Karimireddy et al. 2019). Applied to the *pod axis* reduction
only: in-pod ICI is fast enough for full-precision gradients, the 8x byte
reduction matters on DCN.

``compressed_psum`` is the shard_map building block (tested on host
devices); the trainer applies ``compress_decompress`` as a drop-in grad
transform when TrainConfig.grad_compression == "int8" so the quantization
*noise* (and error feedback) is bit-identical to what the two-stage
reduction would produce.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_decompress(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 round trip: returns (grads_hat, new_residual)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quant(x)
        xh = _dequant(q, s, g.shape)
        return xh.astype(g.dtype), x - xh

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8 all-reduce inside shard_map: agree on a shared per-block scale
    (one tiny pmax), quantize against it, psum the int8 payloads in int32
    (safe for <= 2^23 participants), dequantize once."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    scale = jax.lax.pmax(scale, axis_name)      # shared wire scale
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return _dequant(qsum.astype(jnp.float32), scale, x.shape)


def bytes_saved(grads: Any) -> Tuple[int, int]:
    """(fp32_bytes, int8_bytes) for reporting in §Perf."""
    n = sum(x.size for x in jax.tree_util.tree_leaves(grads))
    fp = n * 4
    q = n * 1 + (n // BLOCK + 1) * 4
    return fp, q
