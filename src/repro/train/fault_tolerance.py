"""Fault tolerance & large-fleet hygiene for the training loop.

Pieces (all exercised by tests and the example driver):
  * ``Heartbeat`` — per-step liveness file + step-duration EWMA; a monitor
    (or a co-scheduled watchdog on a real cluster) declares the worker dead
    when the heartbeat goes stale and triggers restart-from-checkpoint.
  * ``StragglerMonitor`` — flags steps slower than k x the EWMA (on a real
    fleet this feeds the controller's hot-swap/evict decision; here it
    also powers tests and the example's logging).
  * ``resume_or_init`` — checkpoint/restart entry point: restores the
    latest durable state (optionally onto a *different* mesh — elastic
    data-axis resize) or builds a fresh one.
  * ``DataSkipper`` — deterministic batch skipping so a restarted run
    consumes exactly the batches the failed run did not finish.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Optional

from . import checkpoint as ckpt_lib


class Heartbeat:
    def __init__(self, path: str, stale_after_s: float = 300.0):
        self.path = path
        self.stale_after_s = stale_after_s
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int, extra: Optional[dict] = None):
        rec = {"step": step, "t": time.time(), **(extra or {})}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, self.path)

    def is_stale(self) -> bool:
        try:
            with open(self.path) as f:
                rec = json.load(f)
        except FileNotFoundError:
            return True
        return (time.time() - rec["t"]) > self.stale_after_s


class StragglerMonitor:
    """EWMA of step durations; ``check`` returns True when the last step is
    a straggler (> factor x EWMA). At fleet scale this signal drives
    hot-spare swap-in; locally it drives logging/tests."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.n_stragglers = 0

    def record(self, duration_s: float) -> bool:
        if self.ewma is None:
            self.ewma = duration_s
            return False
        is_straggler = duration_s > self.factor * self.ewma
        if is_straggler:
            self.n_stragglers += 1
            # straggler steps do not poison the EWMA
        else:
            self.ewma = (1 - self.alpha) * self.ewma \
                + self.alpha * duration_s
        return is_straggler


class DataSkipper:
    """Deterministic seed-per-step batching: after restore at step k, the
    pipeline regenerates batch k+1 exactly, so no data is skipped or
    duplicated across restarts."""

    def __init__(self, base_seed: int):
        self.base_seed = base_seed

    def seed_for_step(self, step: int) -> int:
        return (self.base_seed * 1_000_003 + step) % (2**31 - 1)


def resume_or_init(ckpt_dir: str, init_fn: Callable[[], Any],
                   target_shape: Any = None, shardings: Any = None,
                   ) -> tuple:
    """(state, start_step). Restores the latest checkpoint if present
    (resharding onto ``shardings`` — the elastic path), else initializes."""
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        return init_fn(), 0
    target = target_shape if target_shape is not None else init_fn()
    state = ckpt_lib.restore(ckpt_dir, target, step=step,
                             shardings=shardings)
    return state, step
