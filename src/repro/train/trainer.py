"""Training step factory: loss -> grads (with optional microbatch
accumulation) -> clip -> AdamW, as a single jit-able function of
(TrainState, batch). Used identically by the real launcher, the examples
and the dry-run (which only lowers it)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..models.model import Model
from . import optimizer as opt_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: opt_lib.OptState


def init_train_state(model: Model, rng) -> TrainState:
    params = model.init_params(rng)
    return TrainState(params=params, opt=opt_lib.init_opt_state(params))


def make_loss_fn(model: Model, objective: str = "lm",
                 remat: bool = True) -> Callable:
    if objective == "lm":
        def loss_fn(params, batch):
            return model.loss_lm(params, batch, remat=remat)
    elif objective == "cox":
        from ..survival import head as head_lib

        def loss_fn(params, batch):
            return head_lib.cox_loss(model, params, batch)
    else:
        raise ValueError(objective)
    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig,
                    objective: str = "lm") -> Callable:
    loss_fn = make_loss_fn(model, objective, remat=tcfg.remat)

    def train_step(state: TrainState, batch) -> tuple:
        if tcfg.microbatch > 1:
            # gradient accumulation: split the batch along dim 0 and scan
            def reshape(x):
                return x.reshape(tcfg.microbatch, x.shape[0]
                                 // tcfg.microbatch, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                return (jax.tree.map(jnp.add, g_sum, g), l_sum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatch, grads)
            loss = loss / tcfg.microbatch
            metrics: Dict[str, Any] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, opt_metrics = opt_lib.adamw_update(
            grads, state.opt, state.params, tcfg)
        out = {"loss": loss, **opt_metrics}
        return TrainState(params=new_params, opt=new_opt), out

    return train_step
