"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX —
optax is not available offline). Moments are fp32 regardless of param dtype;
with the sharding rules in launch/sharding.py they are ZeRO-sharded."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    step: jax.Array


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def lr_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, opt: OptState, params, cfg: TrainConfig):
    """Returns (new_params, new_opt, metrics)."""
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt.m)
    flat_v = tdef.flatten_up_to(opt.v)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(m=new_m, v=new_v, step=step), \
        {"grad_norm": gnorm, "lr": lr}
