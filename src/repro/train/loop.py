"""Reusable host-side training loop.

One place for the step-loop boilerplate every driver was re-growing
(examples, the deep-survival pipeline, ad-hoc benches): iterate a jitted
``step_fn`` over a deterministic stream, keep the loss history, optionally
heartbeat + straggler-monitor + async-checkpoint. The production launcher
(``launch/train.py``) keeps its own loop because it also owns mesh setup
and resume; this one is the library-call form of the same contract —
``stream.batch_for_step(step)`` in, ``(state, metrics)`` out, losses
recorded per step.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Tuple

from . import fault_tolerance as ft


def run_loop(step_fn: Callable, state: Any, stream: Any, steps: int, *,
             start_step: int = 0,
             log_every: int = 25, log_prefix: str = "[train]",
             checkpointer=None, ckpt_every: int = 0,
             heartbeat_path: str = "",
             on_step: Optional[Callable[[int, dict], None]] = None,
             ) -> Tuple[Any, List[float]]:
    """Run ``steps - start_step`` steps; returns (final state, losses).

    ``checkpointer``: a ``train.checkpoint.AsyncCheckpointer`` (saved every
    ``ckpt_every`` steps and once at the end, then waited on).
    ``on_step(step, metrics)`` fires after every step with host floats.
    """
    hb = ft.Heartbeat(heartbeat_path) if heartbeat_path else None
    mon = ft.StragglerMonitor()
    losses: List[float] = []
    for step in range(start_step, steps):
        t0 = time.time()
        state, metrics = step_fn(state, stream.batch_for_step(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        straggler = mon.record(time.time() - t0)
        if hb is not None:
            hb.beat(step, {"loss": loss})
        if log_every and (step % log_every == 0 or straggler):
            tag = " STRAGGLER" if straggler else ""
            print(f"{log_prefix} step {step} loss {loss:.4f}{tag}",
                  flush=True)
        if on_step is not None:
            on_step(step, metrics)
        if checkpointer is not None and ckpt_every \
                and (step + 1) % ckpt_every == 0:
            checkpointer.save(step + 1, state)
    if checkpointer is not None:
        checkpointer.save(steps, state)
        checkpointer.wait()
    return state, losses
