"""Nonconvex separable penalties for the surrogate CD framework:
SCAD (Fan & Li 2001) and MCP (Zhang 2010) — the extensions §3.5 of the
paper names next to LASSO/ElasticNet.

For the quadratic surrogate  a·D + ½ b·D² + pen(|c + D|)  the coordinate
update is the penalty's scalar proximal operator evaluated at the Newton
point z = c − a/b with weight 1/b; both SCAD and MCP have closed forms
when b is large enough (we guard the nonconvex branch by clamping the
effective curvature), so the CD sweep stays analytic exactly as in the
l1 case.

prox derivations (threshold lam, curvature w = 1/b):
  MCP  (gamma > 1):  |z| <= lam w          -> 0
                     |z| <= gamma lam      -> soft(z, lam w)/(1 - w/gamma)
                     else                  -> z
  SCAD (gamma > 2):  |z| <= lam (1 + w)    -> soft(z, lam w)
                     |z| <= gamma lam      -> soft(z, gamma lam w/(gamma-1))
                                              / (1 - w/(gamma-1))
                     else                  -> z
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
_EPS = 1e-12


def _soft(z, t):
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)


def mcp_value(beta: Array, lam: float, gamma: float = 3.0) -> Array:
    a = jnp.abs(beta)
    quad = lam * a - a * a / (2.0 * gamma)
    flat = 0.5 * gamma * lam * lam
    return jnp.sum(jnp.where(a <= gamma * lam, quad, flat))


def scad_value(beta: Array, lam: float, gamma: float = 3.7) -> Array:
    a = jnp.abs(beta)
    lin = lam * a
    quad = (2.0 * gamma * lam * a - a * a - lam * lam) / (2.0 * (gamma - 1.0))
    flat = lam * lam * (gamma + 1.0) / 2.0
    return jnp.sum(jnp.where(a <= lam, lin,
                             jnp.where(a <= gamma * lam, quad, flat)))


def mcp_prox(a: Array, b: Array, c: Array, lam: Array,
             gamma: float = 3.0) -> Array:
    """argmin_D a D + 1/2 b D^2 + MCP(|c + D|; lam, gamma) - returns D."""
    b = jnp.maximum(b, _EPS)
    w = 1.0 / b
    z = c - a * w
    az = jnp.abs(z)
    denom = jnp.maximum(1.0 - w / gamma, 1e-3)  # guard: surrogate curvature
    inner = _soft(z, lam * w) / denom
    new = jnp.where(az <= gamma * lam, inner, z)
    return new - c


def scad_prox(a: Array, b: Array, c: Array, lam: Array,
              gamma: float = 3.7) -> Array:
    b = jnp.maximum(b, _EPS)
    w = 1.0 / b
    z = c - a * w
    az = jnp.abs(z)
    r1 = _soft(z, lam * w)
    denom = jnp.maximum(1.0 - w / (gamma - 1.0), 1e-3)
    r2 = _soft(z, gamma * lam * w / (gamma - 1.0)) / denom
    new = jnp.where(az <= lam * (1.0 + w), r1,
                    jnp.where(az <= gamma * lam, r2, z))
    return new - c


PROX = {"mcp": mcp_prox, "scad": scad_prox}
VALUE = {"mcp": mcp_value, "scad": scad_value}
