"""Cox proportional hazards: losses, exact partial derivatives, Lipschitz
constants.

Implements Theorem 3.1 / Corollary 3.3 / Theorem 3.4 of FastSurvival
(Liu, Zhang, Rudin; NeurIPS 2024).

Conventions
-----------
All functions operate on *time-sorted* data (ascending observation time).
With samples sorted ascending, the risk set ``R_i = {j : t_j >= t_i}`` is the
suffix starting at ``risk_start[i]`` (ties handled Breslow-style: every
member of a tie group shares the group's first index). All risk-set
statistics therefore become reverse (suffix) cumulative sums — the paper's
O(n) "hidden blessing".

Key quantities (all O(n) to form):
  w_k  = exp(eta_k - max eta)                (stabilized hazards)
  rc0  = revcumsum(w)            -> S0_i = rc0[risk_start[i]]
  d_i  = delta_i / S0_i
  A_k  = cumsum(d)[tie_end[k]]   = sum_{i : t_i <= t_k} delta_i / S0_i
  B_k  = cumsum(delta/S0^2)[tie_end[k]]

Swapped-order ("GEMV") identities used for all-coordinate derivatives:
  grad      = X^T (w * A) - X^T delta
  hess_diag = X^T.^2 (w * A) - sum_i delta_i * M_i.^2,
              M_i = revcumsum(w * X)[risk_start[i]] / S0_i
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# python float (weak-typed in jax): an np.float64 scalar here would promote
# the whole Lipschitz pipeline to f64 whenever jax_enable_x64 is on
INV_6_SQRT3 = float(1.0 / (6.0 * np.sqrt(3.0)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CoxData:
    """Time-sorted survival design matrix and risk-set indexing."""

    x: Array          # (n, p) features, sorted ascending by time
    delta: Array      # (n,)   event indicator in {0., 1.}, sorted
    risk_start: Array  # (n,)  int32: first index of each sample's tie group
    tie_end: Array     # (n,)  int32: last index of each sample's tie group

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def p(self) -> int:
        return self.x.shape[1]


def prepare(x: Array, t: Array, delta: Array) -> CoxData:
    """Sort by time ascending and build Breslow tie-group indices."""
    x = jnp.asarray(x)
    t = jnp.asarray(t)
    delta = jnp.asarray(delta, dtype=x.dtype)
    order = jnp.argsort(t, stable=True)
    ts = t[order]
    risk_start = jnp.searchsorted(ts, ts, side="left").astype(jnp.int32)
    tie_end = (jnp.searchsorted(ts, ts, side="right") - 1).astype(jnp.int32)
    return CoxData(
        x=x[order], delta=delta[order], risk_start=risk_start, tie_end=tie_end
    )


def revcumsum(v: Array, axis: int = 0) -> Array:
    """Reverse (suffix) cumulative sum along ``axis``."""
    return jax.lax.cumsum(v, axis=axis, reverse=True)


# ---------------------------------------------------------------------------
# Shared risk-set statistics
# ---------------------------------------------------------------------------

def hazard_weights(eta: Array) -> Tuple[Array, Array]:
    """Stabilized w = exp(eta - m); returns (w, m)."""
    m = jax.lax.stop_gradient(jnp.max(eta))
    return jnp.exp(eta - m), m


def risk_stats(data: CoxData, eta: Array) -> Tuple[Array, Array, Array, Array]:
    """Return (w, s0, a, b) — the O(n) sufficient statistics.

    s0_i = sum_{j in R_i} w_j           (at each sample's risk_start)
    a_k  = sum_{i : t_i <= t_k} delta_i / s0_i
    b_k  = sum_{i : t_i <= t_k} delta_i / s0_i^2
    """
    w, _ = hazard_weights(eta)
    rc0 = revcumsum(w)
    s0 = rc0[data.risk_start]
    d1 = data.delta / s0
    a = jnp.cumsum(d1)[data.tie_end]
    b = jnp.cumsum(d1 / s0)[data.tie_end]
    return w, s0, a, b


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_from_eta(data: CoxData, eta: Array) -> Array:
    """Negative log partial likelihood (Breslow ties), Eq. (4)."""
    m = jnp.max(eta)
    w = jnp.exp(eta - m)
    rc0 = revcumsum(w)
    log_s0 = jnp.log(rc0[data.risk_start]) + m
    return jnp.sum(data.delta * (log_s0 - eta))


def penalty(beta: Array, lam1: Array | float, lam2: Array | float) -> Array:
    return lam1 * jnp.sum(jnp.abs(beta)) + lam2 * jnp.sum(beta * beta)


def objective(
    data: CoxData, beta: Array, lam1: float = 0.0, lam2: float = 0.0
) -> Array:
    eta = data.x @ beta
    return loss_from_eta(data, eta) + penalty(beta, lam1, lam2)


def eta_gradient(data: CoxData, eta: Array) -> Array:
    """d loss / d eta (n,): w*A - delta. Used by deep survival heads."""
    w, _, a, _ = risk_stats(data, eta)
    return w * a - data.delta


# ---------------------------------------------------------------------------
# All-coordinate derivatives (swapped-order GEMV form) — beyond-paper batch
# ---------------------------------------------------------------------------

def grad_all(data: CoxData, eta: Array) -> Array:
    """Exact gradient for all p coordinates in O(np) via two GEMVs."""
    r = eta_gradient(data, eta)  # (n,)
    return data.x.T @ r


def grad_hess_all(data: CoxData, eta: Array) -> Tuple[Array, Array]:
    """Exact (grad, diag Hessian) for all p coordinates, O(np)."""
    w, s0, a, _ = risk_stats(data, eta)
    wa = w * a
    grad = data.x.T @ (wa - data.delta)
    # term1_l = sum_k w_k A_k x_kl^2
    term1 = (data.x * data.x).T @ wa
    # term2_l = sum_i delta_i * (revcumsum(w x_l)[rs_i] / s0_i)^2
    mean = revcumsum(w[:, None] * data.x, axis=0)[data.risk_start] / s0[:, None]
    term2 = (data.delta[:, None] * mean * mean).sum(axis=0)
    return grad, term1 - term2


def exact_hessian(data: CoxData, eta: Array) -> Array:
    """Full (p, p) Hessian in O(n p^2) without materializing the (n, n)
    sample-space Hessian:  X^T diag(w*A) X  -  sum_i delta_i m_i m_i^T."""
    w, s0, a, _ = risk_stats(data, eta)
    h1 = (data.x * (w * a)[:, None]).T @ data.x
    mean = revcumsum(w[:, None] * data.x, axis=0)[data.risk_start] / s0[:, None]
    mw = mean * jnp.sqrt(data.delta)[:, None]
    return h1 - mw.T @ mw


def eta_hessian_diag(data: CoxData, eta: Array) -> Array:
    """Diagonal of the sample-space Hessian nabla^2_eta loss (n,):
    w_k A_k - w_k^2 B_k. Used by the quasi-Newton baseline (Simon et al.)."""
    w, _, a, b = risk_stats(data, eta)
    return w * a - (w * w) * b


def eta_hessian_upper(data: CoxData, eta: Array) -> Array:
    """skglm-style diagonal majorant of nabla^2_eta loss: grad_eta + delta
    = w*A (elementwise, >= diag of the true Hessian)."""
    w, _, a, _ = risk_stats(data, eta)
    return w * a


# ---------------------------------------------------------------------------
# Per-coordinate derivatives (Theorem 3.1) — the paper's CD primitives
# ---------------------------------------------------------------------------

def coord_derivs(
    data: CoxData, eta: Array, xl: Array, order: int = 2
) -> Tuple[Array, Array, Array]:
    """(g, h, c3) = 1st/2nd/3rd partial at one coordinate, each O(n).

    ``xl`` is the (n,) feature column (time-sorted). ``order`` controls how
    many cumulants are formed (2 -> g,h; 3 -> also the third partial).
    """
    w, _ = hazard_weights(eta)
    rc0 = revcumsum(w)
    rc1 = revcumsum(w * xl)
    s0 = rc0[data.risk_start]
    m1 = rc1[data.risk_start] / s0
    g = jnp.sum(data.delta * (m1 - xl))
    rc2 = revcumsum(w * xl * xl)
    m2 = rc2[data.risk_start] / s0
    h = jnp.sum(data.delta * (m2 - m1 * m1))
    if order < 3:
        return g, h, jnp.zeros_like(g)
    rc3 = revcumsum(w * xl * xl * xl)
    m3 = rc3[data.risk_start] / s0
    c3 = jnp.sum(data.delta * (m3 + 2.0 * m1**3 - 3.0 * m2 * m1))
    return g, h, c3


# ---------------------------------------------------------------------------
# Lipschitz constants (Theorem 3.4) — beta-independent, precomputed once
# ---------------------------------------------------------------------------

def lipschitz_constants(data: CoxData) -> Tuple[Array, Array]:
    """(L2, L3), each (p,): L2 bounds the 2nd partial, L3 the |3rd| partial.

    L2_l = 1/4      sum_i delta_i (max_{k in R_i} X_kl - min_{k in R_i})^2
    L3_l = 1/(6√3)  sum_i delta_i |range|^3
    Suffix max/min over the sorted time axis are O(n) reverse cum-extrema.
    """
    smax = jax.lax.cummax(data.x, axis=0, reverse=True)[data.risk_start]
    smin = jax.lax.cummin(data.x, axis=0, reverse=True)[data.risk_start]
    rng = smax - smin
    d = data.delta[:, None]
    l2 = 0.25 * jnp.sum(d * rng * rng, axis=0)
    l3 = INV_6_SQRT3 * jnp.sum(d * rng * rng * rng, axis=0)
    return l2, l3


def central_moment(data: CoxData, eta: Array, xl: Array, r: int) -> Array:
    """C_r of Lemma 3.2 for every event i, returned delta-masked (n,).

    Reference implementation used by tests of the moment recursion
    dC_r/dbeta_l = C_{r+1} - r C_2 C_{r-1}; O(n * r)."""
    w, _ = hazard_weights(eta)
    rc0 = revcumsum(w)
    s0 = rc0[data.risk_start]
    m1 = revcumsum(w * xl)[data.risk_start] / s0
    # E[(X - mu)^r] = sum_j binom(r,j) E[X^j] (-mu)^(r-j)
    out = jnp.zeros_like(s0)
    from math import comb

    for j in range(r + 1):
        ej = revcumsum(w * xl**j)[data.risk_start] / s0
        out = out + comb(r, j) * ej * (-m1) ** (r - j)
    return out
