"""Training algorithms for (regularized) CPH.

Ours (the paper's contribution):
  * ``cd_quad``  — coordinate descent on the quadratic surrogate (Eq. 15/17/20)
  * ``cd_cubic`` — coordinate descent on the cubic surrogate (Eq. 16/18/22)

Baselines (Section 2):
  * ``newton``        — exact Newton, full Hessian in beta space (O(n p^2)
                        via the swapped-order identity; no line search, which
                        is exactly the flaw the paper demonstrates)
  * ``newton_ls``     — exact Newton + backtracking (reference optimum)
  * ``quasi_newton``  — glmnet/Simon et al.: diagonal sample-space Hessian,
                        inner CD on the fixed quadratic model
  * ``prox_newton``   — skglm: diagonal majorant w*A, inner CD likewise
  * ``gd``            — proximal gradient with the global 1/L step from the
                        paper's Lipschitz constants (ISTA)

Every solver minimizes  loss(beta) + lam1 ||beta||_1 + lam2 ||beta||_2^2
and returns the objective trace so benchmarks can reproduce Fig. 1 / App. D.

Telemetry: every fit function takes a static ``telemetry`` argument (an
``obs.TelemetryCallback`` or None). When set, each outer iteration emits
(objective, smooth-part gradient norm, ||step||, nnz(beta)) to the host
via ``jax.debug.callback``, and consecutive objective increases beyond
the callback's tol are counted as monotonicity violations — the paper's
descent guarantee as a production invariant. ``telemetry=None`` (the
default) traces the exact pre-telemetry graph: no callback op, no extra
gradient evaluations. Reuse one instance per solver to avoid retraces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import cox, surrogate
from ..obs import solver as obs_solver

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FitResult:
    beta: Array        # (p,)
    objective: Array   # (n_iters,) objective after each outer iteration
    n_iters: Array     # scalar int (== len unless early-stopped variant)


def _objective(data: cox.CoxData, eta: Array, beta: Array, lam1, lam2) -> Array:
    return cox.loss_from_eta(data, eta) + cox.penalty(beta, lam1, lam2)


def _emit(telemetry, data, it, eta, beta, beta_prev, obj, lam2) -> None:
    """Stage one telemetry callback (traced code; no-op when disabled).

    Gradient norm is of the smooth part (loss + l2) — the standard
    convergence diagnostic that exists for every solver here, l1 or not.
    The extra ``grad_all`` is only paid when telemetry is on.
    """
    if telemetry is None:
        return
    g = cox.grad_all(data, eta) + 2.0 * lam2 * beta
    obs_solver.emit_iter(telemetry, it, obj, jnp.linalg.norm(g),
                         jnp.linalg.norm(beta - beta_prev),
                         jnp.sum(beta != 0))


# ---------------------------------------------------------------------------
# Coordinate descent (ours)
# ---------------------------------------------------------------------------

def _cd_sweep(data: cox.CoxData, eta: Array, beta: Array, l2c: Array,
              l3c: Array, lam1, lam2, cubic: bool,
              use_kernel: bool = False) -> Tuple[Array, Array]:
    """One full sweep over all p coordinates (sequential, lax.fori_loop)."""
    xT = data.x.T  # (p, n)

    if use_kernel:
        from repro.kernels import ops as _kops

    def body(l, carry):
        eta, beta = carry
        xl = xT[l]
        if use_kernel:
            g, h = _kops.cox_coord_grad_hess(eta, xl, data.delta)
        else:
            g, h, _ = cox.coord_derivs(data, eta, xl, order=2)
        bl = beta[l]
        a = g + 2.0 * lam2 * bl
        if cubic:
            step = surrogate.cubic_l1_prox(
                a, h + 2.0 * lam2, l3c[l], bl, lam1)
        else:
            step = surrogate.quad_l1_prox(a, l2c[l] + 2.0 * lam2, bl, lam1)
        beta = beta.at[l].add(step)
        eta = eta + step * xl
        return eta, beta

    return jax.lax.fori_loop(0, data.p, body, (eta, beta))


@partial(jax.jit, static_argnames=("n_iters", "method", "use_kernel",
                                   "telemetry"))
def fit_cd(data: cox.CoxData, lam1: float = 0.0, lam2: float = 0.0,
           n_iters: int = 100, beta0: Optional[Array] = None,
           method: str = "cd_quad", use_kernel: bool = False,
           telemetry=None) -> FitResult:
    """FastSurvival coordinate descent (quadratic or cubic surrogate).

    use_kernel=True routes the per-coordinate derivatives through the fused
    Pallas kernel (kernels/cox_coord.py) — TPU fast path; requires tie-free
    (strictly increasing) event times, see kernels/ops.py."""
    cubic = method == "cd_cubic"
    beta = jnp.zeros(data.p, data.x.dtype) if beta0 is None else beta0
    eta = data.x @ beta
    l2c, l3c = cox.lipschitz_constants(data)

    def step(carry, it):
        eta, beta = carry
        beta_prev = beta
        eta, beta = _cd_sweep(data, eta, beta, l2c, l3c, lam1, lam2, cubic,
                              use_kernel=use_kernel)
        obj = _objective(data, eta, beta, lam1, lam2)
        _emit(telemetry, data, it, eta, beta, beta_prev, obj, lam2)
        return (eta, beta), obj

    (eta, beta), obj = jax.lax.scan(step, (eta, beta),
                                    jnp.arange(n_iters))
    return FitResult(beta=beta, objective=obj, n_iters=jnp.int32(n_iters))


@partial(jax.jit, static_argnames=("max_iters", "method", "telemetry"))
def fit_cd_tol(data: cox.CoxData, lam1: float = 0.0, lam2: float = 0.0,
               max_iters: int = 200, tol: float = 1e-7,
               beta0: Optional[Array] = None,
               method: str = "cd_quad", telemetry=None) -> FitResult:
    """Early-stopping variant (while_loop): stops when the objective
    decrease over one sweep falls below ``tol`` (monotonicity is guaranteed
    by the surrogate majorization, so this is a sound criterion)."""
    cubic = method == "cd_cubic"
    beta = jnp.zeros(data.p, data.x.dtype) if beta0 is None else beta0
    eta = data.x @ beta
    l2c, l3c = cox.lipschitz_constants(data)
    f0 = _objective(data, eta, beta, lam1, lam2)

    def cond(state):
        _, _, prev, cur, it = state
        return (it < max_iters) & (prev - cur > tol)

    def body(state):
        eta, beta, _, cur, it = state
        beta_prev = beta
        eta, beta = _cd_sweep(data, eta, beta, l2c, l3c, lam1, lam2, cubic)
        obj = _objective(data, eta, beta, lam1, lam2)
        _emit(telemetry, data, it, eta, beta, beta_prev, obj, lam2)
        return eta, beta, cur, obj, it + 1

    state = (eta, beta, f0 + 2.0 * tol + 1.0, f0, jnp.int32(0))
    eta, beta, _, cur, it = jax.lax.while_loop(cond, body, state)
    return FitResult(beta=beta, objective=cur[None], n_iters=it)


# ---------------------------------------------------------------------------
# Streaming mini-batch CD (BigSurvSGD-style) — large-n path
# ---------------------------------------------------------------------------

def fit_stream(source, lam1: float = 0.0, lam2: float = 0.0,
               n_epochs: int = 200, tol: float = 0.0,
               mode: str = "global", beta0: Optional[Array] = None,
               telemetry=None, use_kernel: Optional[bool] = None,
               max_backtracks: int = 30) -> FitResult:
    """Streaming proximal diagonal-Newton fit over a chunk source.

    ``source`` is any indexable of ``streaming.Chunk``s (``len`` +
    ``[i]``); the full design matrix is never materialized — per epoch
    the chunks are streamed through ``core/streaming.py``'s carried
    suffix-sum statistics, so the working set is one chunk plus O(n)
    scalar caches.

    ``mode="global"`` optimizes the exact full-stream partial likelihood
    (chunks must be globally time-sorted and tie-free) and therefore
    converges to the same optimum as ``fit_cd``; ``mode="chunk"`` is the
    BigSurvSGD estimand — each chunk its own stratum, no cross-chunk
    risk sets, no global-order requirement.

    The update is an all-coordinates quadratic prox step at the exact
    diagonal Hessian, with objective backtracking (the diagonal is not a
    majorizer, so the paper's automatic-descent property is restored by
    halving the step scale until the streamed objective decreases —
    guaranteeing monotonicity, which telemetry verifies live). The fixed
    point is unchanged by the damping: step 0 at a coordinate iff the
    KKT condition holds there.

    Host-orchestrated (one Python loop per epoch), eager jnp per chunk;
    telemetry fires eagerly through the same ``TelemetryCallback``.
    """
    from . import streaming

    if mode == "global":
        grad_hess = streaming.streaming_grad_hess
        loss_fn = streaming.streaming_loss
    elif mode == "chunk":
        def grad_hess(src, b, use_kernel=None):
            return streaming.stratified_grad_hess(src, b, use_kernel)

        def loss_fn(src, b, use_kernel=None):
            return streaming.stratified_loss(src, b)
    else:
        raise ValueError(f"unknown mode: {mode!r}")

    p = source[0].x.shape[1]
    dtype = source[0].x.dtype
    beta = jnp.zeros(p, dtype) if beta0 is None else beta0
    obj = loss_fn(source, beta) + cox.penalty(beta, lam1, lam2)
    objs = []
    step_scale = 1.0
    it = 0
    for it in range(n_epochs):
        g_s, h_s, _ = grad_hess(source, beta, use_kernel=use_kernel)
        g = g_s + 2.0 * lam2 * beta
        h = jnp.maximum(h_s + 2.0 * lam2, 1e-12)
        cand, new_obj = beta, obj
        for _ in range(max_backtracks):
            step = surrogate.quad_l1_prox(g, h / step_scale, beta, lam1)
            cand = beta + step
            new_obj = loss_fn(source, cand) + cox.penalty(cand, lam1, lam2)
            if float(new_obj) <= float(obj):
                break
            step_scale *= 0.5
        else:
            objs.append(obj)   # no descent step left: converged
            break
        prev, beta, obj = obj, cand, new_obj
        objs.append(obj)
        if telemetry is not None:
            obs_solver.emit_iter(telemetry, jnp.int32(it), obj,
                                 jnp.linalg.norm(g), jnp.linalg.norm(step),
                                 jnp.sum(beta != 0))
        step_scale = min(step_scale * 2.0, 1.0)
        if tol > 0.0 and float(prev) - float(obj) < tol:
            break
    return FitResult(beta=beta, objective=jnp.stack(objs),
                     n_iters=jnp.int32(it + 1))


# ---------------------------------------------------------------------------
# Newton-type baselines
# ---------------------------------------------------------------------------

def _newton_direction(data, eta, beta, lam2) -> Tuple[Array, Array]:
    g = cox.grad_all(data, eta) + 2.0 * lam2 * beta
    h = cox.exact_hessian(data, eta) + 2.0 * lam2 * jnp.eye(data.p, dtype=eta.dtype)
    h = h + 1e-9 * jnp.eye(data.p, dtype=eta.dtype)
    return jnp.linalg.solve(h, -g), g


@partial(jax.jit, static_argnames=("n_iters", "line_search", "telemetry"))
def fit_newton(data: cox.CoxData, lam2: float = 0.0, n_iters: int = 50,
               beta0: Optional[Array] = None,
               line_search: bool = False, telemetry=None) -> FitResult:
    """Exact Newton (lam1 unsupported, as in the paper). ``line_search=True``
    adds Armijo backtracking and serves as the high-precision reference."""
    beta = jnp.zeros(data.p, data.x.dtype) if beta0 is None else beta0

    def step(carry, it):
        beta = carry
        beta_prev = beta
        eta = data.x @ beta
        d, g = _newton_direction(data, eta, beta, lam2)
        if line_search:
            f0 = _objective(data, eta, beta, 0.0, lam2)
            gd = g @ d

            def ls_body(state):
                t, _ = state
                return t * 0.5, _objective(
                    data, data.x @ (beta + t * 0.5 * d), beta + t * 0.5 * d,
                    0.0, lam2)

            def ls_cond(state):
                t, f = state
                return (f > f0 + 1e-4 * t * gd) & (t > 1e-8)

            f1 = _objective(data, data.x @ (beta + d), beta + d, 0.0, lam2)
            t, _ = jax.lax.while_loop(ls_cond, ls_body, (1.0, f1))
            beta = beta + t * d
        else:
            beta = beta + d
        eta = data.x @ beta
        obj = _objective(data, eta, beta, 0.0, lam2)
        _emit(telemetry, data, it, eta, beta, beta_prev, obj, lam2)
        return beta, obj

    beta, obj = jax.lax.scan(step, beta, jnp.arange(n_iters))
    return FitResult(beta=beta, objective=obj, n_iters=jnp.int32(n_iters))


def _inner_cd_quadratic(data: cox.CoxData, dvec: Array, g: Array, beta: Array,
                        lam1, lam2, sweeps: int) -> Array:
    """Solve min_D g^T D + 1/2 D^T X^T diag(dvec) X D + pen(beta + D) by CD.

    Maintains r = diag(dvec) X D so each coordinate touch is O(n); this is
    the glmnet inner loop (all-coefficients-at-once quadratic model)."""
    xT = data.x.T
    q = jnp.maximum((data.x * data.x * dvec[:, None]).sum(0), 1e-12)  # (p,)

    def coord(l, carry):
        delta, r = carry
        xl = xT[l]
        a = g[l] + xl @ r + 2.0 * lam2 * (beta[l] + delta[l])
        b = q[l] + 2.0 * lam2
        step = surrogate.quad_l1_prox(a, b, beta[l] + delta[l], lam1)
        return delta.at[l].add(step), r + (step * dvec) * xl

    def sweep(_, carry):
        return jax.lax.fori_loop(0, data.p, coord, carry)

    delta0 = jnp.zeros_like(beta)
    r0 = jnp.zeros_like(dvec)
    delta, _ = jax.lax.fori_loop(0, sweeps, sweep, (delta0, r0))
    return delta


@partial(jax.jit, static_argnames=("n_iters", "variant", "inner_sweeps",
                                   "telemetry"))
def fit_working_newton(data: cox.CoxData, lam1: float = 0.0, lam2: float = 0.0,
                       n_iters: int = 50, beta0: Optional[Array] = None,
                       variant: str = "quasi",
                       inner_sweeps: int = 3, telemetry=None) -> FitResult:
    """quasi_newton (Simon et al. 2011) / prox_newton (skglm) baselines."""
    beta = jnp.zeros(data.p, data.x.dtype) if beta0 is None else beta0

    def step(carry, it):
        beta = carry
        beta_prev = beta
        eta = data.x @ beta
        g = cox.grad_all(data, eta)
        if variant == "quasi":
            dvec = cox.eta_hessian_diag(data, eta)
        else:
            dvec = cox.eta_hessian_upper(data, eta)
        dvec = jnp.maximum(dvec, 1e-12)
        delta = _inner_cd_quadratic(data, dvec, g, beta, lam1, lam2,
                                    inner_sweeps)
        beta = beta + delta
        eta = data.x @ beta
        obj = _objective(data, eta, beta, lam1, lam2)
        _emit(telemetry, data, it, eta, beta, beta_prev, obj, lam2)
        return beta, obj

    beta, obj = jax.lax.scan(step, beta, jnp.arange(n_iters))
    return FitResult(beta=beta, objective=obj, n_iters=jnp.int32(n_iters))


@partial(jax.jit, static_argnames=("n_iters", "telemetry"))
def fit_gd(data: cox.CoxData, lam1: float = 0.0, lam2: float = 0.0,
           n_iters: int = 200, beta0: Optional[Array] = None,
           telemetry=None) -> FitResult:
    """Proximal gradient (ISTA) with the paper-derived global step 1/L,
    L = sum_l L2_l + 2 lam2 (trace bound on the Hessian spectrum)."""
    beta = jnp.zeros(data.p, data.x.dtype) if beta0 is None else beta0
    l2c, _ = cox.lipschitz_constants(data)
    lr = 1.0 / (jnp.sum(l2c) + 2.0 * lam2 + 1e-12)

    def step(carry, it):
        beta = carry
        beta_prev = beta
        eta = data.x @ beta
        g = cox.grad_all(data, eta) + 2.0 * lam2 * beta
        z = beta - lr * g
        beta = jnp.sign(z) * jnp.maximum(jnp.abs(z) - lr * lam1, 0.0)
        eta = data.x @ beta
        obj = _objective(data, eta, beta, lam1, lam2)
        _emit(telemetry, data, it, eta, beta, beta_prev, obj, lam2)
        return beta, obj

    beta, obj = jax.lax.scan(step, beta, jnp.arange(n_iters))
    return FitResult(beta=beta, objective=obj, n_iters=jnp.int32(n_iters))


SOLVERS = {
    "cd_quad": lambda data, lam1, lam2, n, b0=None: fit_cd(
        data, lam1, lam2, n, b0, method="cd_quad"),
    "cd_cubic": lambda data, lam1, lam2, n, b0=None: fit_cd(
        data, lam1, lam2, n, b0, method="cd_cubic"),
    "newton": lambda data, lam1, lam2, n, b0=None: fit_newton(
        data, lam2, n, b0, line_search=False),
    "newton_ls": lambda data, lam1, lam2, n, b0=None: fit_newton(
        data, lam2, n, b0, line_search=True),
    "quasi_newton": lambda data, lam1, lam2, n, b0=None: fit_working_newton(
        data, lam1, lam2, n, b0, variant="quasi"),
    "prox_newton": lambda data, lam1, lam2, n, b0=None: fit_working_newton(
        data, lam1, lam2, n, b0, variant="prox"),
    "gd": lambda data, lam1, lam2, n, b0=None: fit_gd(data, lam1, lam2, n, b0),
}


@partial(jax.jit, static_argnames=("n_iters", "penalty"))
def fit_cd_penalized(data: cox.CoxData, penalty: str = "scad",
                     lam1: float = 0.1, gamma: float = 3.7,
                     lam2: float = 0.0, n_iters: int = 100,
                     beta0: Optional[Array] = None) -> FitResult:
    """Quadratic-surrogate CD with nonconvex separable penalties (SCAD /
    MCP — the §3.5 extensions). Same O(n) coordinate machinery; the
    coordinate update is the penalty prox at the surrogate's Newton point.
    Objective trace uses the true penalized objective; descent still holds
    per coordinate because the prox minimizes the majorizer exactly."""
    from . import penalties

    prox = penalties.PROX[penalty]
    pval = penalties.VALUE[penalty]
    beta = jnp.zeros(data.p, data.x.dtype) if beta0 is None else beta0
    eta = data.x @ beta
    l2c, _ = cox.lipschitz_constants(data)
    xT = data.x.T

    def sweep(carry, _):
        eta, beta = carry

        def body(l, c):
            eta, beta = c
            g, _, _ = cox.coord_derivs(data, eta, xT[l], order=2)
            a = g + 2.0 * lam2 * beta[l]
            step = prox(a, l2c[l] + 2.0 * lam2, beta[l], lam1, gamma)
            return eta + step * xT[l], beta.at[l].add(step)

        eta, beta = jax.lax.fori_loop(0, data.p, body, (eta, beta))
        obj = cox.loss_from_eta(data, eta) + lam2 * jnp.sum(beta * beta) \
            + pval(beta, lam1, gamma)
        return (eta, beta), obj

    (eta, beta), obj = jax.lax.scan(sweep, (eta, beta), None,
                                    length=n_iters)
    return FitResult(beta=beta, objective=obj, n_iters=jnp.int32(n_iters))
