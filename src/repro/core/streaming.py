"""Streaming (chunked) Cox partial-likelihood statistics.

The FastSurvival blessing — every risk-set statistic is a suffix/prefix
cumulative sum over the time-sorted axis — survives chunking: a suffix
sum over ``concat(chunks)`` equals per-chunk local suffix sums plus a
carried running total from later chunks. This module exploits that to
compute the *exact* full-likelihood loss / gradient / diagonal Hessian
while only ever holding one (chunk_rows, p) block of the design matrix,
plus O(n) scalar caches (eta, s0) that are negligible next to X.

Two estimands (both used by ``solvers.fit_stream``):

* **global** — the exact partial likelihood of the whole stream. Chunks
  must arrive in ascending-time order with tie-free times (the kernels'
  fast-path contract); three passes over the chunk source per evaluation
  (forward eta, reverse suffix stats, forward prefix stats).
* **chunk** (BigSurvSGD, PAPERS.md) — each chunk is treated as its own
  stratum with an independent risk set. The summed per-stratum partial
  likelihood is a consistent estimating function for the same beta; one
  pass, no cross-chunk carry, and no global-order requirement.

Chunk sources are anything indexable: ``len(source)`` and
``source[i] -> Chunk``. A list of ``Chunk``s works; ``as_chunks`` wraps
an in-memory ``CoxData``; benchmarks stream chunks from a generator
factory so the full matrix never exists.

Heavy per-chunk work can route through the existing Pallas kernels
(``kernels/ops.revcumsum`` / ``ops.cox_batch_grad_hess``); the default
``use_kernel=None`` resolves backend-aware (native on TPU, pure-jnp on
CPU where Pallas runs in interpret mode).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import cox

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One time-contiguous block of a survival design matrix."""

    x: Array      # (m, p) features, time-sorted within the chunk
    delta: Array  # (m,)   event indicators

    @property
    def rows(self) -> int:
        return self.x.shape[0]


class ChunkView:
    """Chunked view over an in-memory ``CoxData`` (tests / small n)."""

    def __init__(self, data: cox.CoxData, chunk_rows: int):
        self._data = data
        self._rows = max(int(chunk_rows), 1)

    def __len__(self) -> int:
        return -(-self._data.n // self._rows)

    def __getitem__(self, i: int) -> Chunk:
        if not 0 <= i < len(self):
            raise IndexError(i)
        lo = i * self._rows
        hi = min(lo + self._rows, self._data.n)
        return Chunk(x=self._data.x[lo:hi], delta=self._data.delta[lo:hi])


def as_chunks(data: cox.CoxData, chunk_rows: int) -> ChunkView:
    """Chunked view of time-sorted data (global mode expects this order)."""
    return ChunkView(data, chunk_rows)


def _resolve_kernel(use_kernel: Optional[bool]) -> bool:
    return (jax.default_backend() == "tpu") if use_kernel is None \
        else bool(use_kernel)


def _local_revcumsum(v: Array, use_kernel: bool) -> Array:
    if use_kernel:
        from ..kernels import ops

        return ops.revcumsum(v)
    return jax.lax.cumsum(v, axis=0, reverse=True)


def chunked_revcumsum(segments: Sequence[Array],
                      use_kernel: Optional[bool] = None) -> List[Array]:
    """Suffix sum of ``concat(segments)`` computed blockwise.

    Iterates the segments youngest-first (reverse), doing a local suffix
    scan per segment plus a carried total of everything later — exactly
    equal to the monolithic ``revcumsum`` for any chunk boundaries.
    Segments may be (m,) or (m, p); the carry is a scalar / (p,) vector.
    """
    kern = _resolve_kernel(use_kernel)
    out: List[Optional[Array]] = [None] * len(segments)
    carry = None
    for i in reversed(range(len(segments))):
        v = segments[i]
        loc = _local_revcumsum(v, kern)
        out[i] = loc if carry is None else loc + carry
        tot = v.sum(axis=0)
        carry = tot if carry is None else carry + tot
    return out  # type: ignore[return-value]


def _trivial_coxdata(x: Array, delta: Array) -> cox.CoxData:
    """Tie-free risk-set indexing for one stratum (risk_start == arange)."""
    idx = jnp.arange(x.shape[0], dtype=jnp.int32)
    return cox.CoxData(x=x, delta=delta, risk_start=idx, tie_end=idx)


# ---------------------------------------------------------------------------
# Exact global-likelihood statistics, chunk at a time
# ---------------------------------------------------------------------------

def _forward_eta(source, beta: Array) -> Tuple[List[Array], Array]:
    """Pass 1: per-chunk linear predictors + the global stabilizer max."""
    etas = []
    m = None
    for i in range(len(source)):
        e = source[i].x @ beta
        etas.append(e)
        em = jnp.max(e)
        m = em if m is None else jnp.maximum(m, em)
    return etas, jax.lax.stop_gradient(m)


def streaming_grad_hess(source, beta: Array,
                        use_kernel: Optional[bool] = None
                        ) -> Tuple[Array, Array, Array]:
    """Exact full-stream (grad, hess_diag, loss) at ``beta``.

    Equals ``cox.grad_hess_all`` / ``cox.loss_from_eta`` on the
    concatenated tie-free data, but the (n, p) matrix is only ever
    touched one chunk at a time:

    * reverse pass — suffix stats s0 (scalar carry) and s1 = suffix(w x)
      ((p,) carry) feed the Hessian mean term and the loss, both pure
      suffix quantities;
    * forward pass — the prefix stat A = cumsum(delta / s0) (scalar
      carry) feeds the swapped-order GEMV gradient and Hessian term1.
    """
    kern = _resolve_kernel(use_kernel)
    k = len(source)
    etas, m = _forward_eta(source, beta)
    p = source[0].x.shape[1]
    dtype = etas[0].dtype

    # pass 2 (reverse): s0 per row, Hessian term2, loss
    carry0 = jnp.zeros((), dtype)
    carry1 = jnp.zeros((p,), dtype)
    term2 = jnp.zeros((p,), dtype)
    loss = jnp.zeros((), dtype)
    s0s: List[Optional[Array]] = [None] * k
    for i in reversed(range(k)):
        c = source[i]
        e = etas[i]
        w = jnp.exp(e - m)
        wx = w[:, None] * c.x
        s0 = _local_revcumsum(w, kern) + carry0
        s1 = _local_revcumsum(wx, kern) + carry1
        mean = s1 / s0[:, None]
        term2 = term2 + (c.delta[:, None] * mean * mean).sum(axis=0)
        loss = loss + jnp.sum(c.delta * (jnp.log(s0) + m - e))
        s0s[i] = s0
        carry0 = carry0 + w.sum()
        carry1 = carry1 + wx.sum(axis=0)

    # pass 3 (forward): prefix A, gradient + Hessian term1
    carry_a = jnp.zeros((), dtype)
    grad = jnp.zeros((p,), dtype)
    term1 = jnp.zeros((p,), dtype)
    for i in range(k):
        c = source[i]
        w = jnp.exp(etas[i] - m)
        d1 = c.delta / s0s[i]
        a = jnp.cumsum(d1) + carry_a
        wa = w * a
        grad = grad + c.x.T @ (wa - c.delta)
        term1 = term1 + (c.x * c.x).T @ wa
        carry_a = carry_a + d1.sum()
    return grad, term1 - term2, loss


def streaming_loss(source, beta: Array,
                   use_kernel: Optional[bool] = None) -> Array:
    """Exact full-stream negative log partial likelihood (two passes)."""
    kern = _resolve_kernel(use_kernel)
    etas, m = _forward_eta(source, beta)
    carry0 = jnp.zeros((), etas[0].dtype)
    loss = jnp.zeros((), etas[0].dtype)
    for i in reversed(range(len(source))):
        c = source[i]
        w = jnp.exp(etas[i] - m)
        s0 = _local_revcumsum(w, kern) + carry0
        loss = loss + jnp.sum(c.delta * (jnp.log(s0) + m - etas[i]))
        carry0 = carry0 + w.sum()
    return loss


# ---------------------------------------------------------------------------
# Chunk-as-stratum (BigSurvSGD) statistics
# ---------------------------------------------------------------------------

def stratum_grad_hess(chunk: Chunk, beta: Array,
                      use_kernel: Optional[bool] = None
                      ) -> Tuple[Array, Array, Array]:
    """(grad, hess_diag, loss) of one chunk treated as its own stratum."""
    eta = chunk.x @ beta
    data = _trivial_coxdata(chunk.x, chunk.delta)
    if _resolve_kernel(use_kernel):
        from ..kernels import ops

        g, h = ops.cox_batch_grad_hess(eta, chunk.x, chunk.delta)
    else:
        g, h = cox.grad_hess_all(data, eta)
    return g, h, cox.loss_from_eta(data, eta)


def stratified_grad_hess(source, beta: Array,
                         use_kernel: Optional[bool] = None
                         ) -> Tuple[Array, Array, Array]:
    """Summed per-stratum (grad, hess_diag, loss) over the chunk source."""
    p = beta.shape[0]
    grad = jnp.zeros((p,), beta.dtype)
    hess = jnp.zeros((p,), beta.dtype)
    loss = jnp.zeros((), beta.dtype)
    for i in range(len(source)):
        g, h, f = stratum_grad_hess(source[i], beta, use_kernel)
        grad, hess, loss = grad + g, hess + h, loss + f
    return grad, hess, loss


def stratified_loss(source, beta: Array) -> Array:
    """Summed per-stratum loss (one pass, no carry)."""
    loss = jnp.zeros((), beta.dtype)
    for i in range(len(source)):
        c = source[i]
        data = _trivial_coxdata(c.x, c.delta)
        loss = loss + cox.loss_from_eta(data, c.x @ beta)
    return loss
