"""Beam-search cardinality-constrained CPH (Section 3.5, "Constrained
Problem").

Support expansion a la generalized OMP + beam search (FasterRisk/OKRidge
style), but — the paper's point — scored and finetuned with the monotone
surrogate coordinate descent, which is what makes the framework usable for
CPH at all (Newton-type inner solvers blow up).

Host-driven outer loop over support sizes (k <= ~30); all inner work is
jitted:
  * ``score_candidates``: for every feature not in the support, run a few
    1-D surrogate steps on that coordinate alone (vmapped over p) and
    measure the *actual* loss decrease — the paper's selection rule
    ("which coefficient, if optimized, results in the largest decrease").
  * ``finetune``: CD sweeps over the (padded) support columns to tolerance.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import cox, surrogate
from ..obs import trace

Array = jax.Array


@dataclasses.dataclass
class BeamResult:
    """Best model per support size: supports[k] has k+1 indices."""
    supports: List[np.ndarray]
    betas: List[np.ndarray]        # dense (p,) coefficient vectors
    losses: List[float]            # unpenalized CPH loss of the best beam


@partial(jax.jit, static_argnames=("steps",))
def score_candidates(data: cox.CoxData, eta: Array, l2c: Array,
                     lam2: float, in_support: Array, steps: int = 4):
    """Loss decrease achievable by optimizing each coordinate alone.

    Returns (decrease (p,), step_total (p,)); support members get -inf.
    """
    base = cox.loss_from_eta(data, eta)

    def one(xl, l2l):
        def body(carry, _):
            eta_l, b = carry
            g, _, _ = cox.coord_derivs(data, eta_l, xl, order=2)
            step = surrogate.quad_min(g + 2.0 * lam2 * b,
                                      l2l + 2.0 * lam2).astype(eta.dtype)
            return (eta_l + step * xl, b + step), None

        (eta_l, b), _ = jax.lax.scan(
            body, (eta, jnp.zeros((), eta.dtype)), None, length=steps)
        dec = base - (cox.loss_from_eta(data, eta_l) + lam2 * b * b)
        return dec, b

    dec, b = jax.vmap(one, in_axes=(1, 0))(data.x, l2c)
    dec = jnp.where(in_support, -jnp.inf, dec)
    return dec, b


@partial(jax.jit, static_argnames=("k_max", "n_sweeps"))
def finetune(data: cox.CoxData, support_idx: Array, support_mask: Array,
             lam2: float, k_max: int, n_sweeps: int = 60):
    """CD (quadratic surrogate) restricted to the padded support columns.

    support_idx: (k_max,) int32 (padding arbitrary), support_mask: (k_max,).
    Returns (beta_s (k_max,), eta (n,), loss).
    """
    cols = data.x[:, support_idx] * support_mask[None, :]  # zero out padding
    l2c, _ = cox.lipschitz_constants(
        cox.CoxData(x=cols, delta=data.delta, risk_start=data.risk_start,
                    tie_end=data.tie_end))

    def sweep(carry, _):
        eta, beta_s = carry

        def body(j, c):
            eta, beta_s = c
            xl = cols[:, j]
            g, _, _ = cox.coord_derivs(data, eta, xl, order=2)
            step = surrogate.quad_min(g + 2.0 * lam2 * beta_s[j],
                                      l2c[j] + 2.0 * lam2)
            step = jnp.where(support_mask[j] > 0, step, 0.0)
            return eta + step * xl, beta_s.at[j].add(step)

        eta, beta_s = jax.lax.fori_loop(0, k_max, body, (eta, beta_s))
        return (eta, beta_s), None

    eta0 = jnp.zeros(data.n, cols.dtype)
    beta0 = jnp.zeros(k_max, cols.dtype)
    (eta, beta_s), _ = jax.lax.scan(sweep, (eta0, beta0), None,
                                    length=n_sweeps)
    return beta_s, eta, cox.loss_from_eta(data, eta)


def beam_search(data: cox.CoxData, k: int, beam_width: int = 5,
                n_expand: int = 8, lam2: float = 1e-3,
                score_steps: int = 4, finetune_sweeps: int = 60,
                telemetry=None) -> BeamResult:
    """Grow supports 1..k, keeping the ``beam_width`` best at each size.

    The outer loop is host-driven, so telemetry is recorded directly (no
    debug callbacks): nested ``beam.score`` / ``beam.finetune`` spans
    around the jitted inner stages and a ``beam.size`` span per support
    size carrying the candidate count and best loss. Pass an
    ``obs.TelemetryCallback`` to additionally emit a tagged ``beam.size``
    event per size (candidates, best loss, chosen support)."""
    l2c, _ = cox.lipschitz_constants(data)
    p = data.p
    # beams: list of (loss, support tuple, eta, beta_s padded)
    beams = [(float(cox.loss_from_eta(data, jnp.zeros(data.n, data.x.dtype))),
              (), jnp.zeros(data.n, data.x.dtype))]
    out = BeamResult(supports=[], betas=[], losses=[])

    with trace.span("beam.search", k=k, beam_width=beam_width, p=p):
        for size in range(1, k + 1):
            with trace.span("beam.size", size=size) as size_span:
                candidates = {}
                with trace.span("beam.score", n_beams=len(beams)):
                    for loss_b, supp, eta_b in beams:
                        mask = np.zeros(p, dtype=bool)
                        mask[list(supp)] = True
                        dec, _ = score_candidates(data, eta_b, l2c, lam2,
                                                  jnp.asarray(mask),
                                                  steps=score_steps)
                        top = np.argsort(-np.asarray(dec))[:n_expand]
                        for l in top:
                            new_supp = tuple(sorted(supp + (int(l),)))
                            if new_supp in candidates:
                                continue
                            candidates[new_supp] = True
                # finetune every unique candidate support
                scored = []
                with trace.span("beam.finetune",
                                n_candidates=len(candidates)):
                    for new_supp in candidates:
                        idx = np.zeros(k, dtype=np.int32)
                        msk = np.zeros(k, dtype=np.float32)
                        idx[: len(new_supp)] = np.asarray(new_supp, np.int32)
                        msk[: len(new_supp)] = 1.0
                        beta_s, eta, loss = finetune(
                            data, jnp.asarray(idx), jnp.asarray(msk), lam2,
                            k, n_sweeps=finetune_sweeps)
                        scored.append((float(loss), new_supp, eta,
                                       np.asarray(beta_s), idx))
                scored.sort(key=lambda s: s[0])
                beams = [(s[0], s[1], s[2]) for s in scored[:beam_width]]
                best = scored[0]
                beta_dense = np.zeros(p, dtype=np.float32)
                beta_dense[best[4][: len(best[1])]] = best[3][: len(best[1])]
                out.supports.append(np.asarray(best[1], np.int64))
                out.betas.append(beta_dense)
                out.losses.append(best[0])
                size_span.set(n_candidates=len(candidates),
                              best_loss=best[0])
                if telemetry is not None:
                    telemetry.record_event(
                        "beam.size", size=size,
                        n_candidates=len(candidates), best_loss=best[0],
                        support=list(map(int, best[1])))
    return out


def omp_greedy(data: cox.CoxData, k: int, lam2: float = 1e-3,
               finetune_sweeps: int = 60) -> BeamResult:
    """Gradient-magnitude OMP baseline (what the paper improves upon):
    pick argmax |grad_l| each round, then finetune. Beam width 1, gradient
    scoring instead of loss-decrease scoring."""
    p = data.p
    supp: tuple = ()
    eta = jnp.zeros(data.n, data.x.dtype)
    out = BeamResult(supports=[], betas=[], losses=[])
    for size in range(1, k + 1):
        g = np.array(cox.grad_all(data, eta))  # copy: jax buffers are read-only
        g[list(supp)] = 0.0
        supp = tuple(sorted(supp + (int(np.argmax(np.abs(g))),)))
        idx = np.zeros(k, dtype=np.int32)
        msk = np.zeros(k, dtype=np.float32)
        idx[: len(supp)] = np.asarray(supp, np.int32)
        msk[: len(supp)] = 1.0
        beta_s, eta, loss = finetune(data, jnp.asarray(idx), jnp.asarray(msk),
                                     lam2, k, n_sweeps=finetune_sweeps)
        beta_dense = np.zeros(p, dtype=np.float32)
        beta_dense[idx[: len(supp)]] = np.asarray(beta_s)[: len(supp)]
        out.supports.append(np.asarray(supp, np.int64))
        out.betas.append(beta_dense)
        out.losses.append(float(loss))
    return out
