"""Regularization paths (coxnet-style l1 / elastic-net) with warm starts.

Used both as a user-facing feature and as the LASSO-path baseline in the
variable-selection benchmarks (SksurvCoxnet analogue, solved with *our*
monotone CD so it cannot blow up).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from . import cox, solvers


@dataclasses.dataclass
class PathResult:
    lambdas: np.ndarray
    betas: np.ndarray          # (n_lambda, p)
    losses: np.ndarray         # unpenalized CPH loss
    support_sizes: np.ndarray


def lambda_max(data: cox.CoxData) -> float:
    """Smallest lam1 for which beta = 0 is optimal: max |grad_l(0)|."""
    eta0 = jnp.zeros(data.n, data.x.dtype)
    return float(jnp.max(jnp.abs(cox.grad_all(data, eta0))))


def l1_path(data: cox.CoxData, n_lambdas: int = 30,
            lambda_min_ratio: float = 0.01, lam2: float = 0.0,
            n_iters: int = 80, method: str = "cd_quad") -> PathResult:
    lmax = lambda_max(data)
    lams = np.geomspace(lmax * 0.999, lmax * lambda_min_ratio, n_lambdas)
    betas, losses, sizes = [], [], []
    beta = jnp.zeros(data.p, data.x.dtype)
    for lam1 in lams:
        res = solvers.fit_cd(data, lam1=float(lam1), lam2=lam2,
                             n_iters=n_iters, beta0=beta, method=method)
        beta = res.beta
        b = np.asarray(beta)
        betas.append(b)
        losses.append(float(cox.loss_from_eta(data, data.x @ beta)))
        sizes.append(int((np.abs(b) > 1e-8).sum()))
    return PathResult(lambdas=lams, betas=np.stack(betas),
                      losses=np.asarray(losses),
                      support_sizes=np.asarray(sizes))


def adaptive_lasso(data: cox.CoxData, lam1: float, lam2: float = 1e-3,
                   n_rounds: int = 3, n_iters: int = 80) -> np.ndarray:
    """Adaptive-LASSO baseline (Zhang & Lu 2007): reweighted l1 where each
    round's weights are 1/|beta_prev|. Implemented by column rescaling so the
    inner problem stays a vanilla l1 fit."""
    beta = np.asarray(
        solvers.fit_cd(data, lam1=lam1, lam2=lam2, n_iters=n_iters).beta)
    for _ in range(n_rounds - 1):
        wts = 1.0 / np.maximum(np.abs(beta), 1e-3)
        scale = 1.0 / wts
        scaled = cox.CoxData(
            x=data.x * jnp.asarray(scale)[None, :], delta=data.delta,
            risk_start=data.risk_start, tie_end=data.tie_end)
        res = solvers.fit_cd(scaled, lam1=lam1, lam2=lam2, n_iters=n_iters)
        beta = np.asarray(res.beta) * scale
    return beta
