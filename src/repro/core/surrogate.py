"""Quadratic and cubic surrogate minimizers and their l1-regularized
analytic solutions (Section 3.4/3.5 and Appendix A.4/A.5 of FastSurvival).

Every function here is a scalar map (jnp-vectorizable, jit/vmap safe,
branchless) so CD sweeps can run inside ``lax.fori_loop``/``scan``.

Notation follows the paper:
  quadratic surrogate at x:  g(D) = f(x) + a D + 1/2 b D^2,   a=f'(x), b=L2
  cubic surrogate at x:      h(D) = f(x) + a D + 1/2 b D^2 + 1/6 c |D|^3,
                             a=f'(x), b=f''(x), c=L3
Ridge (lam2 ||.||^2) is absorbed by a += 2 lam2 x, b += 2 lam2 (footnote 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
_EPS = 1e-12


def quad_min(a: Array, b: Array) -> Array:
    """argmin a*D + 1/2 b D^2  =  -a/b (Eq. 17)."""
    return -a / jnp.maximum(b, _EPS)


def cubic_min(a: Array, b: Array, c: Array) -> Array:
    """argmin a*D + 1/2 b D^2 + 1/6 c |D|^3 (Eq. 18).

    = sgn(a) * (b - sqrt(b^2 + 2 c |a|)) / c, with a Newton fallback as
    c -> 0. Numerically rewritten to avoid catastrophic cancellation:
    (b - sqrt(b^2 + 2c|a|))/c = -2|a| / (b + sqrt(b^2 + 2c|a|)).
    """
    c = jnp.maximum(c, 0.0)
    disc = jnp.sqrt(b * b + 2.0 * c * jnp.abs(a))
    step = -2.0 * jnp.abs(a) / jnp.maximum(b + disc, _EPS)
    return jnp.sign(a) * step


def quad_l1_prox(a: Array, b: Array, c: Array, lam1: Array) -> Array:
    """argmin a*D + 1/2 b D^2 + lam1 |c + D|  (Eq. 20); c = current coord.

    Equivalent to soft-thresholding the Newton point of the surrogate.
    """
    b = jnp.maximum(b, _EPS)
    u = b * c - a
    z = jnp.sign(u) * jnp.maximum(jnp.abs(u) - lam1, 0.0) / b  # new coord value
    return z - c


def _cubic_piece_value(delta: Array, a: Array, b: Array, c: Array,
                       lam1: Array, d: Array) -> Array:
    """Objective a D + 1/2 b D^2 + 1/6 c |D|^3 + lam1 |d + D|."""
    return (a * delta + 0.5 * b * delta * delta
            + (c / 6.0) * jnp.abs(delta) ** 3 + lam1 * jnp.abs(d + delta))


def cubic_l1_prox(a: Array, b: Array, c: Array, d: Array, lam1: Array) -> Array:
    """argmin_D a D + 1/2 b D^2 + 1/6 c |D|^3 + lam1 |d + D| (Eq. 21/22).

    Robust candidate-enumeration form: the objective is piecewise smooth with
    kinks at D = 0 (from |D|^3's derivative pieces) and D = -d; on each
    smooth piece the stationary point solves a quadratic. We enumerate every
    stationary candidate clamped to its validity interval plus both kinks and
    take the argmin — branchless, exactly equivalent to the paper's Eq. (22)
    case analysis but immune to sgn(0) edge cases.
    """
    a, b, c, d, lam1 = map(jnp.asarray, (a, b, c, d, lam1))
    c = jnp.maximum(c, 0.0)
    cands = []
    # Pieces indexed by (sign of D -> s3 in {+1,-1}, sign of d+D -> s1):
    # derivative: a + b D + s3 * c/2 D^2 + s1 * lam1 = 0
    for s3 in (1.0, -1.0):
        for s1 in (1.0, -1.0):
            aa = 0.5 * s3 * c
            bb = b
            cc = a + s1 * lam1
            disc = bb * bb - 4.0 * aa * cc
            sq = jnp.sqrt(jnp.maximum(disc, 0.0))
            valid = disc >= 0.0
            for sgn in (1.0, -1.0):
                # quadratic root (guard aa ~ 0 -> linear root)
                root_q = (-bb + sgn * sq) / jnp.where(
                    jnp.abs(2.0 * aa) < _EPS, jnp.inf, 2.0 * aa
                )
                root_l = -cc / jnp.where(jnp.abs(bb) < _EPS, jnp.inf, bb)
                root = jnp.where(jnp.abs(aa) < _EPS, root_l, root_q)
                # validity: sign(root) == s3 and sign(d + root) == s1
                ok = (
                    valid
                    & (root * s3 >= 0.0)
                    & ((d + root) * s1 >= 0.0)
                    & jnp.isfinite(root)
                )
                cands.append(jnp.where(ok, root, 0.0))
    cands.append(jnp.zeros_like(a))      # kink at D = 0
    cands.append(-d)                     # kink at D = -d
    cand = jnp.stack(cands)
    vals = _cubic_piece_value(cand, a, b, c, lam1, d)
    return cand[jnp.argmin(vals)]


def cubic_l1_prox_paper(a: Array, b: Array, c: Array, d: Array,
                        lam1: Array) -> Array:
    """Eq. (22) unified formula, with the appendix-correct signs.

    NOTE (reproduction finding): the unified formula printed as Eq. (22) in
    the main text has ``(b + sqrt(b^2 + 2c(...)))/c`` in its second and third
    branches, but the case-by-case derivation in Appendix A.5 (cases 3 and 5
    for d>=0, cases 1 and 3 for d<0) yields ``(b - sqrt(...))/c`` — with the
    published "+" the step lands on the wrong side of 0 (e.g. a=1, b=0, c=1,
    d=1, lam1=0 gives +sqrt(2) instead of the true minimizer -sqrt(2)). We
    follow the appendix; tests cross-check against grid search and against
    the branch-free candidate solver above.

    Valid when d != 0 (paper's case analysis); sgn(0) handled by falling
    back to the d=0 analysis (threshold at |a| <= lam1).
    """
    c = jnp.maximum(c, _EPS)
    s = jnp.sign(d)
    cond1 = s * a + lam1 <= 0.0
    cond2 = s * (a - b * d) - 0.5 * c * d * d > lam1
    cond3 = s * (a - b * d) - 0.5 * c * d * d < -lam1
    r1 = s * (-b + jnp.sqrt(jnp.maximum(b * b - 2.0 * c * (s * a + lam1), 0.0))) / c
    r2 = s * (b - jnp.sqrt(jnp.maximum(b * b + 2.0 * c * (s * a - lam1), 0.0))) / c
    r3 = s * (b - jnp.sqrt(jnp.maximum(b * b + 2.0 * c * (s * a + lam1), 0.0))) / c
    out = jnp.where(cond1, r1, jnp.where(cond2, r2, jnp.where(cond3, r3, -d)))
    # d == 0: soft-threshold then one-sided cubic root
    a0 = jnp.abs(a) - lam1
    zero_step = jnp.where(
        a0 <= 0.0,
        0.0,
        -jnp.sign(a) * 2.0 * a0 / (b + jnp.sqrt(b * b + 2.0 * c * a0)),
    )
    return jnp.where(d == 0.0, zero_step, out)


def quad_decrease(a: Array, b: Array) -> Array:
    """Guaranteed decrease of the quadratic surrogate: a^2 / (2b).
    Used by beam search to score candidate coordinates."""
    return 0.5 * a * a / jnp.maximum(b, _EPS)
