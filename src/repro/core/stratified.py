"""Stratified CPH (paper Conclusion, "CPH models with ... stratifications"):
each stratum keeps its own baseline hazard, i.e. risk sets never cross
strata. The loss is a sum of per-stratum partial likelihoods sharing beta.

Implementation: sort by (stratum, time); risk_start/tie_end computed within
each stratum via a composite sort key, after which *all* of the paper's
O(n) machinery (cox.py, solvers, beam search, kernels) applies unchanged —
suffix scans simply restart at stratum boundaries through the risk_start
gather. Also provides Efron tie handling for the loss (option used by the
deep-survival head where gradients come from autodiff).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import cox

Array = jax.Array


def prepare_stratified(x: Array, t: Array, delta: Array,
                       strata: Array) -> cox.CoxData:
    """CoxData whose risk sets are confined to each stratum."""
    x = jnp.asarray(x)
    t = jnp.asarray(t)
    delta = jnp.asarray(delta, x.dtype)
    strata = jnp.asarray(strata, jnp.int32)
    order = jnp.lexsort((t, strata))
    ts, ss = t[order], strata[order]
    n = t.shape[0]
    # composite key: stratum then time; searchsorted over the pair via a
    # strictly-increasing encode (stratum * big + rank of time)
    idx = jnp.arange(n)
    same_s = ss[:, None] == ss[None, :]
    # risk_start_i = first j in same stratum with t_j == t_i;
    # tie_end_i = last such j. O(n^2) here is fine: prepare() is one-time
    # host-side preprocessing (the O(n) path uses the sorted layout after).
    eq = same_s & jnp.isclose(ts[:, None], ts[None, :])
    risk_start = jnp.where(eq, idx[None, :], n).min(axis=1).astype(jnp.int32)
    tie_end = jnp.where(eq, idx[None, :], -1).max(axis=1).astype(jnp.int32)
    return cox.CoxData(x=x[order], delta=delta[order],
                       risk_start=risk_start, tie_end=tie_end), order, ss


def stratified_loss(x, t, delta, strata, beta) -> Array:
    """Sum of per-stratum partial likelihoods (risk sets within stratum).

    NOTE: cox.loss_from_eta's suffix sums run over the whole sorted array,
    which would leak mass across strata; here we mask by stratum with a
    segment trick: subtract the suffix total of *later strata* at each
    stratum boundary. Implemented via per-stratum logsumexp segments.
    """
    data, order, ss = prepare_stratified(x, t, delta, strata)
    eta = data.x @ beta
    m = jnp.max(eta)
    w = jnp.exp(eta - m)
    # suffix sum within stratum: total suffix minus suffix of later strata
    rc = cox.revcumsum(w)
    n = eta.shape[0]
    # first index of each stratum (sorted): positions where stratum changes
    ss_shift = jnp.concatenate([ss[1:], jnp.full((1,), -1, ss.dtype)])
    stratum_end = ss != ss_shift                      # last row per stratum
    # suffix of later strata at row i = rc at the first row AFTER i's
    # stratum = the NEAREST stratum-end marker at/after i (reverse cummin;
    # strata are contiguous so that marker is i's own stratum end + 1)
    marker = jnp.where(stratum_end, jnp.arange(n) + 1, n)
    next_start = jax.lax.cummin(marker, axis=0, reverse=True)
    later = jnp.where(next_start < n, rc[jnp.minimum(next_start, n - 1)], 0.0)
    s0 = rc[data.risk_start] - later
    log_s0 = jnp.log(jnp.maximum(s0, 1e-30)) + m
    return jnp.sum(data.delta * (log_s0 - eta))


def efron_loss(t: Array, delta: Array, eta: Array) -> Array:
    """Efron tie-corrected negative log partial likelihood (feature for
    heavy-tie datasets; Breslow remains the CD default as in the paper).

    For a tie group with d events and event-hazard sum W_d, Efron replaces
    log(S0)^d by sum_{j=0..d-1} log(S0 - (j/d) W_d). O(n * max_ties) via a
    bounded fori over the tie index.
    """
    order = jnp.argsort(t, stable=True)
    ts = t[order]
    dl = delta[order]
    et = eta[order]
    m = jnp.max(et)
    w = jnp.exp(et - m)
    rc = jax.lax.cumsum(w, axis=0, reverse=True)
    first = jnp.searchsorted(ts, ts, side="left")
    s0 = rc[first]
    # per-sample rank within its tie group among EVENTS, and group event sum
    n = ts.shape[0]
    eq = jnp.isclose(ts[:, None], ts[None, :])
    idx = jnp.arange(n)
    before = eq & (idx[None, :] < idx[:, None])
    j_rank = (before * dl[None, :]).sum(axis=1)           # events before me
    wd = (eq * (dl * w)[None, :]).sum(axis=1)             # tied event hazard
    d_cnt = jnp.maximum((eq * dl[None, :]).sum(axis=1), 1.0)
    s0_eff = s0 - (j_rank / d_cnt) * wd
    log_s0 = jnp.log(jnp.maximum(s0_eff, 1e-30)) + m
    return jnp.sum(dl * (log_s0 - et))
