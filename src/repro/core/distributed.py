"""Distributed FastSurvival: the paper's O(n) machinery sharded over the
production mesh (n over `data`, p over `model`).

The scan structure distributes cleanly (DESIGN.md §3):
  * suffix sums: local suffix-scan per shard + one psum of shard totals,
    combined with an exclusive suffix over shard index — a log-depth
    distributed scan implemented in shard_map;
  * the all-coordinate GEMV form is a sharded matvec (XLA inserts a single
    psum over `model` / reduce-scatter over `data`);
  * a CD *sweep* keeps eta resident and sharded; each coordinate touch
    moves only O(1) scalars across the mesh.

`fit_cd_sharded` is the paper-representative workload of the §Perf
hillclimb; `sharded_grad_hess_all` powers distributed beam-search scoring.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import cox, surrogate

Array = jax.Array


def shard_revcumsum(x: Array, mesh, axis: str = "data") -> Array:
    """Suffix sum of a (n,) array sharded over ``axis``: local suffix scan
    + exclusive suffix of per-shard totals (one all-gather of scalars)."""

    def local(xs):
        idx = jax.lax.axis_index(axis)
        n_sh = jax.lax.axis_size(axis)
        loc = jax.lax.cumsum(xs, axis=0, reverse=True)
        totals = jax.lax.all_gather(xs.sum(), axis)          # (n_sh,)
        right = jnp.where(jnp.arange(n_sh) > idx, totals, 0.0).sum()
        return loc + right

    return jax.shard_map(local, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis))(x)


def sharded_risk_stats(data: cox.CoxData, eta: Array, mesh):
    """(w, s0, a) with every (n,) vector sharded over `data`.

    Tie-free fast path (risk_start == arange), matching the Pallas kernels'
    contract; ties fall back to the replicated path in core.cox.
    """
    def local(eta_l, delta_l):
        ax = "data"
        idx = jax.lax.axis_index(ax)
        n_sh = jax.lax.axis_size(ax)
        m = jax.lax.pmax(jnp.max(eta_l), ax)
        w = jnp.exp(eta_l - m)
        # suffix sum of w
        loc = jax.lax.cumsum(w, axis=0, reverse=True)
        totals = jax.lax.all_gather(w.sum(), ax)
        s0 = loc + jnp.where(jnp.arange(n_sh) > idx, totals, 0.0).sum()
        # prefix sum of delta / s0
        d1 = delta_l / s0
        locp = jnp.cumsum(d1)
        totals_p = jax.lax.all_gather(d1.sum(), ax)
        a = locp + jnp.where(jnp.arange(n_sh) < idx, totals_p, 0.0).sum()
        return w, s0, a

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(P("data"), P("data")),
                         out_specs=(P("data"), P("data"), P("data")))(
        eta, data.delta)


def sharded_grad_hess_all(data: cox.CoxData, eta: Array, mesh
                          ) -> Tuple[Array, Array]:
    """All-coordinate (grad, diag hess): X sharded (data, model), result
    sharded over `model`. GEMV form -> XLA emits one psum over `data`."""
    w, s0, a = sharded_risk_stats(data, eta, mesh)
    wa = w * a
    grad = data.x.T @ (wa - data.delta)
    term1 = (data.x * data.x).T @ wa
    # mean term needs the suffix scan of w * x per column (n, p)
    wx = w[:, None] * data.x
    s1 = shard_revcumsum_2d(wx, mesh)
    mean = s1 / s0[:, None]
    term2 = (data.delta[:, None] * mean * mean).sum(axis=0)
    return grad, term1 - term2


def shard_revcumsum_2d(x: Array, mesh) -> Array:
    def local(xs):
        ax = "data"
        idx = jax.lax.axis_index(ax)
        n_sh = jax.lax.axis_size(ax)
        loc = jax.lax.cumsum(xs, axis=0, reverse=True)
        totals = jax.lax.all_gather(xs.sum(axis=0), ax)      # (n_sh, p_loc)
        right = (jnp.where((jnp.arange(n_sh) > idx)[:, None], totals, 0.0)
                 .sum(axis=0))
        return loc + right[None, :]

    return jax.shard_map(local, mesh=mesh, in_specs=P("data", "model"),
                         out_specs=P("data", "model"))(x)


@partial(jax.jit, static_argnames=("n_sweeps", "mesh"))
def fit_cd_sharded(data: cox.CoxData, l2c: Array, mesh,
                   lam1: float = 0.0, lam2: float = 0.0,
                   n_sweeps: int = 10):
    """Quadratic-surrogate CD with n sharded over `data` and the feature
    matrix sharded (data, model). Per coordinate: one sharded suffix scan
    (O(n/shards) + scalar collectives) and one sharded axpy on eta."""
    xT = data.x.T  # (p, n)
    beta = jnp.zeros(data.p, data.x.dtype)
    eta = jnp.zeros(data.n, data.x.dtype)

    def coord(l, carry):
        eta, beta = carry
        xl = xT[l]
        w, s0, a = sharded_risk_stats(data, eta, mesh)
        # grad_l = sum_k w_k a_k x_kl - sum delta x  (tie-free GEMV form)
        g = jnp.sum((w * a - data.delta) * xl)
        step = surrogate.quad_l1_prox(g + 2.0 * lam2 * beta[l],
                                      l2c[l] + 2.0 * lam2, beta[l], lam1)
        return eta + step * xl, beta.at[l].add(step)

    def sweep(_, carry):
        return jax.lax.fori_loop(0, data.p, coord, carry)

    eta, beta = jax.lax.fori_loop(0, n_sweeps, sweep, (eta, beta))
    return beta, eta
