"""Distributed FastSurvival: the paper's O(n) machinery sharded over the
production mesh (n over `data`, p over `model`).

The scan structure distributes cleanly (DESIGN.md §3):
  * suffix sums: local suffix-scan per shard + one psum of shard totals,
    combined with an exclusive suffix over shard index — a log-depth
    distributed scan implemented in shard_map;
  * the all-coordinate GEMV form is a sharded matvec (XLA inserts a single
    psum over `model` / reduce-scatter over `data`);
  * a CD *sweep* keeps eta resident and sharded; each coordinate touch
    moves only O(1) scalars across the mesh.

Remainder shards: none of the entry points require ``n`` divisible by the
``data`` axis size. Inputs are zero-padded at the *tail* of the time axis
(the youngest suffix positions, so suffix sums over real rows are
untouched) and a 0/1 mask zeroes the padded hazards — ``w = 0`` and
``delta = 0`` on pad rows kill every risk-set and gradient contribution,
and ``s0`` is clamped to 1 there so no 0/0 NaN can leak through a psum.

`fit_cd_sharded` is the paper-representative workload of the §Perf
hillclimb; `sharded_grad_hess_all` powers distributed beam-search scoring.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import cox, surrogate
from ..launch.mesh import shard_map_compat

Array = jax.Array


def _axis_size(mesh, axis: str = "data") -> int:
    return int(mesh.shape[axis])


def _pad0(v: Array, size: int) -> Array:
    """Zero-pad axis 0 up to a multiple of ``size``."""
    pad = (-v.shape[0]) % size
    if pad == 0:
        return v
    widths = [(0, pad)] + [(0, 0)] * (v.ndim - 1)
    return jnp.pad(v, widths)


def _mask_for(n: int, size: int, dtype) -> Array:
    """(n_padded,) 1.0 on real rows, 0.0 on the padded tail."""
    n_pad = n + ((-n) % size)
    return (jnp.arange(n_pad) < n).astype(dtype)


def shard_revcumsum(x: Array, mesh, axis: str = "data") -> Array:
    """Suffix sum of a (n,) array sharded over ``axis``: local suffix scan
    + exclusive suffix of per-shard totals (one all-gather of scalars).
    ``n`` need not divide the axis size (zero tail-padding is exact for
    suffix sums)."""

    n_sh = _axis_size(mesh, axis)

    def local(xs):
        idx = jax.lax.axis_index(axis)
        loc = jax.lax.cumsum(xs, axis=0, reverse=True)
        totals = jax.lax.all_gather(xs.sum(), axis)          # (n_sh,)
        right = jnp.where(jnp.arange(n_sh) > idx, totals, 0.0).sum()
        return loc + right

    n = x.shape[0]
    out = shard_map_compat(local, mesh=mesh, in_specs=P(axis),
                           out_specs=P(axis))(_pad0(x, _axis_size(mesh, axis)))
    return out[:n]


def _risk_stats_local(n_sh: int):
    """Per-shard body: (w, s0_safe, a) on padded shapes (``data`` axis)."""
    ax = "data"

    def local(eta_l, delta_l, mask_l):
        idx = jax.lax.axis_index(ax)
        m = jax.lax.pmax(jnp.max(jnp.where(mask_l > 0, eta_l, -jnp.inf)), ax)
        w = jnp.exp(eta_l - m) * mask_l
        # suffix sum of w
        loc = jax.lax.cumsum(w, axis=0, reverse=True)
        totals = jax.lax.all_gather(w.sum(), ax)
        s0 = loc + jnp.where(jnp.arange(n_sh) > idx, totals, 0.0).sum()
        s0 = jnp.where(mask_l > 0, s0, 1.0)  # pad rows: no 0/0 downstream
        # prefix sum of delta / s0
        d1 = delta_l / s0
        locp = jnp.cumsum(d1)
        totals_p = jax.lax.all_gather(d1.sum(), ax)
        a = locp + jnp.where(jnp.arange(n_sh) < idx, totals_p, 0.0).sum()
        return w, s0, a

    return local


def _risk_stats_padded(eta_p: Array, delta_p: Array, mask: Array, mesh):
    return shard_map_compat(
        _risk_stats_local(_axis_size(mesh)), mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P("data")))(eta_p, delta_p, mask)


def sharded_risk_stats(data: cox.CoxData, eta: Array, mesh):
    """(w, s0, a) with every (n,) vector sharded over `data`.

    Tie-free fast path (risk_start == arange), matching the Pallas kernels'
    contract; ties fall back to the replicated path in core.cox. Handles
    n not divisible by the data-axis size via a masked padded tail.
    """
    n = eta.shape[0]
    size = _axis_size(mesh)
    mask = _mask_for(n, size, eta.dtype)
    w, s0, a = _risk_stats_padded(_pad0(eta, size), _pad0(data.delta, size),
                                  mask, mesh)
    return w[:n], s0[:n], a[:n]


def sharded_grad_hess_all(data: cox.CoxData, eta: Array, mesh
                          ) -> Tuple[Array, Array]:
    """All-coordinate (grad, diag hess): X sharded (data, model), result
    sharded over `model`. GEMV form -> XLA emits one psum over `data`."""
    n = eta.shape[0]
    size = _axis_size(mesh)
    xp = _pad0(data.x, size)
    dp = _pad0(data.delta, size)
    mask = _mask_for(n, size, eta.dtype)
    w, s0, a = _risk_stats_padded(_pad0(eta, size), dp, mask, mesh)
    wa = w * a
    grad = xp.T @ (wa - dp)
    term1 = (xp * xp).T @ wa
    # mean term needs the suffix scan of w * x per column (n, p)
    wx = w[:, None] * xp
    s1 = shard_revcumsum_2d(wx, mesh)
    mean = s1 / s0[:, None]
    term2 = (dp[:, None] * mean * mean).sum(axis=0)
    return grad, term1 - term2


def shard_revcumsum_2d(x: Array, mesh) -> Array:
    n_sh = _axis_size(mesh)

    def local(xs):
        ax = "data"
        idx = jax.lax.axis_index(ax)
        loc = jax.lax.cumsum(xs, axis=0, reverse=True)
        totals = jax.lax.all_gather(xs.sum(axis=0), ax)      # (n_sh, p_loc)
        right = (jnp.where((jnp.arange(n_sh) > idx)[:, None], totals, 0.0)
                 .sum(axis=0))
        return loc + right[None, :]

    n = x.shape[0]
    out = shard_map_compat(local, mesh=mesh, in_specs=P("data", "model"),
                           out_specs=P("data", "model"))(
        _pad0(x, _axis_size(mesh)))
    return out[:n]


@partial(jax.jit, static_argnames=("n_sweeps", "mesh"))
def fit_cd_sharded(data: cox.CoxData, l2c: Array, mesh,
                   lam1: float = 0.0, lam2: float = 0.0,
                   n_sweeps: int = 10):
    """Quadratic-surrogate CD with n sharded over `data` and the feature
    matrix sharded (data, model). Per coordinate: one sharded suffix scan
    (O(n/shards) + scalar collectives) and one sharded axpy on eta."""
    size = _axis_size(mesh)
    xp = _pad0(data.x, size)
    dp = _pad0(data.delta, size)
    mask = _mask_for(data.n, size, data.x.dtype)
    xT = xp.T  # (p, n_padded)
    beta = jnp.zeros(data.p, data.x.dtype)
    eta = jnp.zeros(xp.shape[0], data.x.dtype)

    def coord(l, carry):
        eta, beta = carry
        xl = xT[l]
        w, s0, a = _risk_stats_padded(eta, dp, mask, mesh)
        # grad_l = sum_k w_k a_k x_kl - sum delta x  (tie-free GEMV form)
        g = jnp.sum((w * a - dp) * xl)
        step = surrogate.quad_l1_prox(g + 2.0 * lam2 * beta[l],
                                      l2c[l] + 2.0 * lam2, beta[l], lam1)
        return eta + step * xl, beta.at[l].add(step)

    def sweep(_, carry):
        return jax.lax.fori_loop(0, data.p, coord, carry)

    eta, beta = jax.lax.fori_loop(0, n_sweeps, sweep, (eta, beta))
    return beta, eta[:data.n]
