"""Pallas TPU kernel: Theorem 3.4 Lipschitz constants in one pass.

    L2_l = 1/4      sum_i delta_i (suffix_max_i(x_l) - suffix_min_i(x_l))^2
    L3_l = 1/(6√3)  sum_i delta_i |range|^3

Same decoupled-scan shape as revcumsum: the grid walks n-blocks
right-to-left over an (n, m) feature panel; in-block suffix max/min run as
log2(block_n) shift-and-max steps on the VPU (static shifts — no
data-dependent gathers), a (2, m) VMEM carry holds the running extrema of
everything to the right, and the delta-weighted reductions accumulate into
(1, m) outputs. Tie-free path (risk set = own suffix), like cox_coord.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INV_6_SQRT3 = float(1.0 / (6.0 * np.sqrt(3.0)))


def _suffix_extreme(v, combine, fill):
    """Suffix-scan along axis 0 of (bn, m) via log-depth doubling."""
    bn = v.shape[0]
    sh = 1
    while sh < bn:
        shifted = jnp.concatenate(
            [v[sh:], jnp.full((sh, v.shape[1]), fill, v.dtype)], axis=0)
        v = combine(v, shifted)
        sh *= 2
    return v


def _kernel(x_ref, d_ref, l2_ref, l3_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0:1, :] = jnp.full_like(carry_ref[0:1, :], -1e30)  # max
        carry_ref[1:2, :] = jnp.full_like(carry_ref[1:2, :], 1e30)   # min
        l2_ref[...] = jnp.zeros_like(l2_ref)
        l3_ref[...] = jnp.zeros_like(l3_ref)

    x = x_ref[...].astype(jnp.float32)          # (bn, m)
    d = d_ref[...].astype(jnp.float32)          # (bn, 1)
    smax = jnp.maximum(_suffix_extreme(x, jnp.maximum, -1e30),
                       carry_ref[0:1, :])
    smin = jnp.minimum(_suffix_extreme(x, jnp.minimum, 1e30),
                       carry_ref[1:2, :])
    rng = smax - smin
    l2_ref[...] += 0.25 * jnp.sum(d * rng * rng, axis=0, keepdims=True)
    l3_ref[...] += jnp.float32(INV_6_SQRT3) * jnp.sum(
        d * rng * rng * rng, axis=0, keepdims=True)
    carry_ref[0:1, :] = jnp.maximum(carry_ref[0:1, :],
                                    jnp.max(x, axis=0, keepdims=True))
    carry_ref[1:2, :] = jnp.minimum(carry_ref[1:2, :],
                                    jnp.min(x, axis=0, keepdims=True))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _lipschitz_jit(x: jax.Array, delta: jax.Array, block_n: int,
                   interpret: bool):
    n, m = x.shape
    nb = pl.cdiv(n, block_n)
    pad = nb * block_n - n
    if pad:
        # pad with values that can never extend the range and delta = 0
        x = jnp.pad(x, ((0, pad), (0, 0)), constant_values=0.0)
        delta = jnp.pad(delta, (0, pad))
        # padded rows sit at the END (latest times): they'd corrupt the
        # suffix extrema of real rows, so replicate the last real row
        x = x.at[n:].set(x[n - 1])
    out_spec = pl.BlockSpec((1, m), lambda i: (0, 0))
    l2, l3 = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (nb - 1 - i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (nb - 1 - i, 0)),
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((2, m), jnp.float32)],
        interpret=interpret,
    )(x, delta.reshape(-1, 1))
    return l2[0], l3[0]


def lipschitz(x: jax.Array, delta: jax.Array, block_n: int = 512,
              interpret: bool | None = None):
    """(L2 (m,), L3 (m,)) for a time-sorted tie-free (n, m) panel.

    ``interpret=None`` resolves backend-aware: native on TPU, interpret
    mode elsewhere. Pass an explicit bool to override (tests).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _lipschitz_jit(x, delta, block_n=block_n, interpret=interpret)
