"""Pallas TPU kernel: blocked reverse (suffix) cumulative sum along axis 0.

The paper's O(n) blessing is a suffix scan; on TPU we implement it as a
decoupled two-phase scan: the grid walks n-blocks right-to-left (sequential
grid ordering on TPU makes the carry legal), each block does its in-block
suffix sum on the MXU via an upper-triangular ones matmul, and a VMEM
scratch row carries the running total of everything to the right.

Input  (n, m)  ->  Output (n, m), out[i, :] = sum_{j >= i} x[j, :].

Block shape (block_n, m): the whole feature panel stays resident; VMEM use
is 2 * block_n * m * 4B + block_n^2 * 4B (the triangular matrix), so e.g.
block_n=512, m=256 is ~1.6 MB — comfortably inside the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _suffix_tri(block_n: int, dtype=jnp.float32):
    """Upper-triangular (incl. diagonal) ones matrix: (U @ x)[i] = sum_{j>=i} x[j]."""
    row = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 1)
    return (col >= row).astype(dtype)


def _revcumsum_kernel(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_n, m)
    u = _suffix_tri(x.shape[0])
    suff = jax.lax.dot_general(
        u, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] = (suff + carry_ref[...]).astype(o_ref.dtype)
    carry_ref[...] = carry_ref[...] + jnp.sum(x, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _revcumsum_jit(x: jax.Array, block_n: int, interpret: bool) -> jax.Array:
    n, m = x.shape
    nb = pl.cdiv(n, block_n)
    pad = nb * block_n - n
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x

    out = pl.pallas_call(
        _revcumsum_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n, m), lambda i: (nb - 1 - i, 0))],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (nb - 1 - i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((1, m), jnp.float32)],
        interpret=interpret,
    )(xp)
    return out[:n]


def revcumsum(x: jax.Array, block_n: int = 512,
              interpret: bool | None = None) -> jax.Array:
    """Suffix cumulative sum along axis 0 of a 2-D array via Pallas.

    ``interpret=None`` (the default) resolves backend-aware: native on TPU,
    interpret mode elsewhere. Pass an explicit bool to override (tests).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _revcumsum_jit(x, block_n=block_n, interpret=interpret)
