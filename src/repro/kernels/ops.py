"""jit'd public wrappers around the Pallas kernels.

Selection logic:
  * on TPU the compiled kernels run natively; elsewhere (this container)
    they run in interpret mode for correctness (the kernels resolve
    ``interpret=None`` backend-aware themselves);
  * block sizes default to the autotuner's winners (kernels/autotune.py):
    every dispatch looks up backend + kernel + power-of-two shape bucket
    in the JSON tune cache and falls back to the historical static
    defaults when the bucket is untuned. Pass an explicit block to pin;
  * data with tied event times falls back to the pure-jnp Breslow
    reference (the kernels implement the tie-free fast path; ties need a
    gather at risk_start which is not worth a TPU kernel — see
    kernels/cox_coord.py).

Telemetry: every dispatch increments ``kernel_dispatch_total`` labelled
with the kernel name and block provenance (``tuned`` cache hit /
``default`` static fallback / ``explicit`` caller-pinned). Counts are
dispatch-side: a kernel traced once inside an outer ``jit`` counts once
per compilation, eager callers count per call — either way, a fleet
silently running default blocks is visible in the metrics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..obs import metrics as obs_metrics
from . import autotune, ref
from .cox_batch import cox_batch as _cox_batch_kernel
from .cox_coord import cox_coord as _cox_coord_kernel
from .revcumsum import revcumsum as _revcumsum_kernel
from .survival_curves import survival_curves as _survival_curves_kernel
from .survival_curves import (
    survival_curves_stratified as _survival_curves_strat_kernel)

_M_DISPATCH = obs_metrics.REGISTRY.counter(
    "kernel_dispatch_total", "Pallas kernel dispatches by block provenance",
    ("kernel", "blocks"))


def _blocks(kernel: str, explicit: bool, **shape):
    """Resolve blocks + count the dispatch under its provenance tag."""
    if explicit:
        _M_DISPATCH.inc(kernel=kernel, blocks="explicit")
        return None
    cfg, tag = autotune.lookup_tagged(kernel, **shape)
    _M_DISPATCH.inc(kernel=kernel, blocks=tag)
    return cfg


def revcumsum(x: jax.Array, block_n: Optional[int] = None) -> jax.Array:
    """Suffix sum along axis 0; accepts (n,) or (n, m)."""
    squeeze = x.ndim == 1
    x2 = x[:, None] if squeeze else x
    cfg = _blocks("revcumsum", block_n is not None,
                  n=x2.shape[0], m=x2.shape[1])
    if block_n is None:
        block_n = cfg["block_n"]
    out = _revcumsum_kernel(x2, block_n=block_n)
    return out[:, 0] if squeeze else out


def cox_coord_grad_hess(eta: jax.Array, x: jax.Array, delta: jax.Array,
                        order: int = 2, block: Optional[int] = None):
    """Fused per-coordinate (g, h) — tie-free fast path."""
    cfg = _blocks("cox_coord", block is not None, n=eta.shape[0])
    if block is None:
        block = cfg["block"]
    g, h, _ = _cox_coord_kernel(eta, x, delta, order=order, block=block)
    return g, h


def cox_coord_all(eta: jax.Array, x: jax.Array, delta: jax.Array,
                  block: Optional[int] = None):
    """Fused per-coordinate (g, h, c3) including the third partial."""
    cfg = _blocks("cox_coord", block is not None, n=eta.shape[0])
    if block is None:
        block = cfg["block"]
    return _cox_coord_kernel(eta, x, delta, order=3, block=block)


def cox_batch_grad_hess(eta: jax.Array, x: jax.Array, delta: jax.Array,
                        block_n: Optional[int] = None,
                        block_p: Optional[int] = None):
    """All-coordinate (grad, hess_diag) — tie-free fast path.

    Precomputes the O(n) vectors in jnp (one pass), then the O(np) panel
    work runs in the kernel.
    """
    cfg = _blocks("cox_batch", block_n is not None and block_p is not None,
                  n=x.shape[0], p=x.shape[1])
    if block_n is None or block_p is None:
        block_n = cfg["block_n"] if block_n is None else block_n
        block_p = cfg["block_p"] if block_p is None else block_p
    eta32 = eta.astype(jnp.float32)
    d32 = delta.astype(jnp.float32)
    w = jnp.exp(eta32 - jnp.max(eta32))
    s0 = jax.lax.cumsum(w, axis=0, reverse=True)
    inv_s0 = 1.0 / s0
    a = jnp.cumsum(d32 * inv_s0)
    wa = w * a
    r = wa - d32
    return _cox_batch_kernel(x, w, r, wa, d32, inv_s0,
                             block_n=block_n, block_p=block_p)


def survival_curves(eta: jax.Array, h0: jax.Array,
                    block_b: Optional[int] = None,
                    block_g: Optional[int] = None) -> jax.Array:
    """Fused (batch x grid) survival curves — the serving hot path."""
    cfg = _blocks("survival_curves",
                  block_b is not None and block_g is not None,
                  b=eta.shape[0], g=h0.shape[0])
    if block_b is None or block_g is None:
        block_b = cfg["block_b"] if block_b is None else block_b
        block_g = cfg["block_g"] if block_g is None else block_g
    return _survival_curves_kernel(eta, h0, block_b=block_b,
                                   block_g=block_g)


def survival_curves_stratified(eta: jax.Array, h0: jax.Array,
                               strata: jax.Array,
                               block_g: Optional[int] = None) -> jax.Array:
    """Per-request-baseline curves; the h0 row gather runs inside the
    kernel via scalar prefetch (h0 is (s, g), strata (b,) int rows)."""
    cfg = _blocks("survival_curves_strat", block_g is not None,
                  b=eta.shape[0], g=h0.shape[1])
    if block_g is None:
        block_g = cfg["block_g"]
    return _survival_curves_strat_kernel(eta, h0, strata, block_g=block_g)


def lipschitz_constants(x: jax.Array, delta: jax.Array,
                        block_n: Optional[int] = None):
    """(L2, L3) Theorem-3.4 constants — tie-free fast path."""
    from .lipschitz import lipschitz as _lips_kernel

    cfg = _blocks("lipschitz", block_n is not None,
                  n=x.shape[0], m=x.shape[1])
    if block_n is None:
        block_n = cfg["block_n"]
    return _lips_kernel(x, delta, block_n=block_n)
