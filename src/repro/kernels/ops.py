"""jit'd public wrappers around the Pallas kernels.

Selection logic:
  * on TPU the compiled kernels run natively;
  * elsewhere (this container) they run in interpret mode for correctness;
  * data with tied event times falls back to the pure-jnp Breslow reference
    (the kernels implement the tie-free fast path; ties need a gather at
    risk_start which is not worth a TPU kernel — see kernels/cox_coord.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .cox_batch import cox_batch as _cox_batch_kernel
from .cox_coord import cox_coord as _cox_coord_kernel
from .revcumsum import revcumsum as _revcumsum_kernel
from .survival_curves import survival_curves as _survival_curves_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def revcumsum(x: jax.Array, block_n: int = 512) -> jax.Array:
    """Suffix sum along axis 0; accepts (n,) or (n, m)."""
    squeeze = x.ndim == 1
    x2 = x[:, None] if squeeze else x
    out = _revcumsum_kernel(x2, block_n=block_n, interpret=_interpret())
    return out[:, 0] if squeeze else out


def cox_coord_grad_hess(eta: jax.Array, x: jax.Array, delta: jax.Array,
                        order: int = 2, block: int = 1024):
    """Fused per-coordinate (g, h) — tie-free fast path."""
    g, h, _ = _cox_coord_kernel(eta, x, delta, order=order, block=block,
                                interpret=_interpret())
    return g, h


def cox_coord_all(eta: jax.Array, x: jax.Array, delta: jax.Array,
                  block: int = 1024):
    """Fused per-coordinate (g, h, c3) including the third partial."""
    return _cox_coord_kernel(eta, x, delta, order=3, block=block,
                             interpret=_interpret())


def cox_batch_grad_hess(eta: jax.Array, x: jax.Array, delta: jax.Array,
                        block_n: int = 512, block_p: int = 256):
    """All-coordinate (grad, hess_diag) — tie-free fast path.

    Precomputes the O(n) vectors in jnp (one pass), then the O(np) panel
    work runs in the kernel.
    """
    eta32 = eta.astype(jnp.float32)
    d32 = delta.astype(jnp.float32)
    w = jnp.exp(eta32 - jnp.max(eta32))
    s0 = jax.lax.cumsum(w, axis=0, reverse=True)
    inv_s0 = 1.0 / s0
    a = jnp.cumsum(d32 * inv_s0)
    wa = w * a
    r = wa - d32
    return _cox_batch_kernel(x, w, r, wa, d32, inv_s0,
                             block_n=block_n, block_p=block_p,
                             interpret=_interpret())


def survival_curves(eta: jax.Array, h0: jax.Array, block_b: int = 256,
                    block_g: int = 128) -> jax.Array:
    """Fused (batch x grid) survival curves — the serving hot path."""
    return _survival_curves_kernel(eta, h0, block_b=block_b,
                                   block_g=block_g, interpret=_interpret())


def lipschitz_constants(x: jax.Array, delta: jax.Array,
                        block_n: int = 512):
    """(L2, L3) Theorem-3.4 constants — tie-free fast path."""
    from .lipschitz import lipschitz as _lips_kernel

    return _lips_kernel(x, delta, block_n=block_n, interpret=_interpret())
