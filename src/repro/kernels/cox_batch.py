"""Pallas TPU kernel: all-coordinate CPH gradient + diagonal Hessian.

The beyond-paper GEMV reframing (DESIGN.md §3): with
    A_k = sum_{i : t_i <= t_k} delta_i / S0_i,     r = w*A - delta,
the full gradient is  X^T r  and the diagonal Hessian is
    (X.^2)^T (w*A)  -  sum_i delta_i * (suffix(w x_l)_i / S0_i)^2.

The kernel tiles (n x p) into (block_n x block_p) VMEM panels on a
(p_blocks, n_blocks) grid with n innermost walked right-to-left, so the
suffix of w*X is carried in a (1, block_p) scratch row per feature panel.
Both reductions run on the MXU. Vectors (w, r, wa, delta, 1/s0) stream in
as (block_n, 1) columns. Tie-free fast path (ops.py precomputes s0/A with
Breslow gathers in jnp and falls back entirely when ties exist).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .revcumsum import _suffix_tri


def _kernel(x_ref, r_ref, wa_ref, w_ref, d_ref, inv_s0_ref,
            g_ref, h_ref, carry_ref):
    i = pl.program_id(1)  # n-block counter (innermost, reversed)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)
        g_ref[...] = jnp.zeros_like(g_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)        # (bn, bp)
    r = r_ref[...].astype(jnp.float32)        # (bn, 1)
    wa = wa_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    inv_s0 = inv_s0_ref[...].astype(jnp.float32)

    def colsum(vec, mat):  # (bn,1)^T @ (bn,bp) -> (1,bp) on the MXU
        return jax.lax.dot_general(
            vec, mat, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    g_ref[...] += colsum(r, x)
    h_ref[...] += colsum(wa, x * x)

    bn = x.shape[0]
    s1 = jax.lax.dot_general(
        _suffix_tri(bn), w * x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + carry_ref[...]
    m = s1 * inv_s0                            # (bn, bp)
    h_ref[...] += -colsum(d, m * m)
    carry_ref[...] = carry_ref[...] + jnp.sum(w * x, axis=0, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_p", "interpret"))
def _cox_batch_jit(x: jax.Array, w: jax.Array, r: jax.Array, wa: jax.Array,
                   delta: jax.Array, inv_s0: jax.Array,
                   block_n: int, block_p: int, interpret: bool):
    n, p = x.shape
    nb = pl.cdiv(n, block_n)
    pb = pl.cdiv(p, block_p)
    pad_n = nb * block_n - n
    pad_p = pb * block_p - p
    xp = jnp.pad(x, ((0, pad_n), (0, pad_p))) if (pad_n or pad_p) else x

    def col(v):
        v = jnp.pad(v, (0, pad_n)) if pad_n else v
        return v.reshape(-1, 1)

    vec_spec = pl.BlockSpec((block_n, 1), lambda j, i: (nb - 1 - i, 0))
    out_spec = pl.BlockSpec((1, block_p), lambda j, i: (0, j))
    g, h = pl.pallas_call(
        _kernel,
        grid=(pb, nb),
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda j, i: (nb - 1 - i, j)),
            vec_spec, vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((1, pb * block_p), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((1, block_p), jnp.float32)],
        interpret=interpret,
    )(xp, col(r), col(wa), col(w), col(delta), col(inv_s0))
    return g[0, :p], h[0, :p]


def cox_batch(x: jax.Array, w: jax.Array, r: jax.Array, wa: jax.Array,
              delta: jax.Array, inv_s0: jax.Array,
              block_n: int = 512, block_p: int = 256,
              interpret: bool | None = None):
    """(grad, hess_diag) for all p coordinates. Inputs time-sorted, no ties.

    x: (n, p); w, r, wa, delta, inv_s0: (n,) precomputed in ops.py.
    ``interpret=None`` resolves backend-aware: native on TPU, interpret
    mode elsewhere. Pass an explicit bool to override (tests).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _cox_batch_jit(x, w, r, wa, delta, inv_s0,
                          block_n=block_n, block_p=block_p,
                          interpret=interpret)
