"""Pallas TPU kernel: fused (batch x time-grid) survival-curve evaluation.

S(t_g | x_b) = exp(-H0[g] * exp(eta[b])) — the serving hot path. The naive
jnp version materializes the (b, g) hazard product in HBM before the exp;
here the outer product runs on the MXU ((block_b, 1) @ (1, block_g)) and
the exp fuses on the VPU, so the (b, g) panel is written to HBM exactly
once. eta is clipped to +/-30 inside the kernel (matching the evaluation
path in survival/metrics.py) so extreme risk scores saturate to 0/1
probabilities instead of overflowing.

Grid: (b_blocks, g_blocks); every block is independent (no carry), so any
grid order is legal. VMEM per step is block_b*block_g*4B + O(block_b +
block_g) — the default 256 x 128 panel is ~128 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _curves_kernel(eta_ref, h0_ref, o_ref):
    eta = jnp.clip(eta_ref[...].astype(jnp.float32), -30.0, 30.0)  # (bb, 1)
    h0 = h0_ref[...].astype(jnp.float32)                           # (1, bg)
    risk = jnp.exp(eta)
    prod = jax.lax.dot_general(
        risk, h0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] = jnp.exp(-prod).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_g", "interpret"))
def _survival_curves_jit(eta: jax.Array, h0: jax.Array, block_b: int,
                         block_g: int, interpret: bool) -> jax.Array:
    b, g = eta.shape[0], h0.shape[0]
    bb = pl.cdiv(b, block_b)
    gb = pl.cdiv(g, block_g)
    pad_b = bb * block_b - b
    pad_g = gb * block_g - g
    etap = jnp.pad(eta, (0, pad_b)) if pad_b else eta
    h0p = jnp.pad(h0, (0, pad_g)) if pad_g else h0

    out = pl.pallas_call(
        _curves_kernel,
        grid=(bb, gb),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_g), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bb * block_b, gb * block_g),
                                       jnp.float32),
        interpret=interpret,
    )(etap.reshape(-1, 1), h0p.reshape(1, -1))
    return out[:b, :g]


def survival_curves(eta: jax.Array, h0: jax.Array, block_b: int = 256,
                    block_g: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """(b, g) survival probabilities from risk scores and baseline hazard.

    eta: (b,) linear predictors; h0: (g,) cumulative baseline hazard on the
    model's time grid (must be >= 0 and nondecreasing).
    ``interpret=None`` resolves backend-aware: native on TPU, interpret
    mode elsewhere. Pass an explicit bool to override (tests).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _survival_curves_jit(eta, h0, block_b=block_b, block_g=block_g,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# Stratified variant: per-request baseline row, gathered via scalar prefetch
# ---------------------------------------------------------------------------

def _curves_strat_kernel(strata_ref, eta_ref, h0_ref, o_ref):
    del strata_ref  # consumed by the index maps, not the body
    eta = jnp.clip(eta_ref[...].astype(jnp.float32), -30.0, 30.0)  # (1, 1)
    h0 = h0_ref[...].astype(jnp.float32)                           # (1, bg)
    o_ref[...] = jnp.exp(-h0 * jnp.exp(eta)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_g", "interpret"))
def _survival_curves_strat_jit(eta: jax.Array, h0: jax.Array,
                               strata: jax.Array, block_g: int,
                               interpret: bool) -> jax.Array:
    b, g = eta.shape[0], h0.shape[1]
    gb = pl.cdiv(g, block_g)
    pad_g = gb * block_g - g
    h0p = jnp.pad(h0, ((0, 0), (0, pad_g))) if pad_g else h0

    out = pl.pallas_call(
        _curves_strat_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, gb),
            in_specs=[
                pl.BlockSpec((1, 1), lambda i, j, s: (i, 0)),
                # the prefetched strata vector drives which baseline row
                # is DMA'd for grid step i — the gather never hits VMEM
                # as a full (b, g) materialized panel
                pl.BlockSpec((1, block_g), lambda i, j, s: (s[i], j)),
            ],
            out_specs=pl.BlockSpec((1, block_g), lambda i, j, s: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, gb * block_g), jnp.float32),
        interpret=interpret,
    )(strata.astype(jnp.int32), eta.reshape(-1, 1), h0p)
    return out[:, :g]


def survival_curves_stratified(eta: jax.Array, h0: jax.Array,
                               strata: jax.Array, block_g: int = 128,
                               interpret: bool | None = None) -> jax.Array:
    """(b, g) curves with a per-request baseline: S = exp(-H0[strata[i]] *
    exp(eta[i])).

    eta: (b,) linear predictors; h0: (s, g) per-stratum cumulative baseline
    hazards; strata: (b,) int row indices into h0. The row gather folds
    into the kernel's index map via scalar prefetch (the ROADMAP
    carry-over): strata rides ahead of the grid in SMEM and selects the
    h0 block DMA per request, so no (b, g) gathered copy of the baselines
    is ever materialized. Grid is (b, g_blocks) — one request per row
    step, eta clipped to +/-30 as in the unstratified kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _survival_curves_strat_jit(eta, h0, strata, block_g=block_g,
                                      interpret=interpret)
