"""Block-size autotuner for the Pallas kernels.

Per kernel (``revcumsum``, ``cox_coord``, ``cox_batch``, ``lipschitz``,
``survival_curves``) and per shape bucket (power-of-two buckets on the
kernel's shape axes, matching the serving engine's batch bucketing),
``autotune()`` times a small candidate grid of block configs with
``block_until_ready``, picks the winner, and persists it to a JSON cache
keyed by ``backend/kernel/bucket``. ``ops.py`` calls ``lookup()`` on every
dispatch — a pure dict read that falls back to the static defaults when a
bucket is untuned, so production paths never pay a timing cost. Winners
are also registered into the roofline registry (``analysis/roofline.py``)
so the report's tuned-blocks table shows tuned vs default.

Cache location: ``$REPRO_TUNE_CACHE`` when set, else
``~/.cache/repro/tuned_blocks.json``. ``benchmarks/run.py`` points the env
var at ``benchmarks/tuned_blocks.json`` so the winners are committed
alongside the ``BENCH_*.json`` trajectory artifacts.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import events as obs_events
from ..obs import profile as obs_profile
from .cox_batch import cox_batch
from .cox_coord import cox_coord
from .lipschitz import lipschitz
from .revcumsum import revcumsum
from .survival_curves import survival_curves, survival_curves_stratified

CACHE_ENV = "REPRO_TUNE_CACHE"
CACHE_VERSION = 1

# the static fallbacks — identical to the historical hard-coded blocks, so
# untuned deployments behave exactly as before
DEFAULT_CONFIGS: Dict[str, Dict[str, int]] = {
    "revcumsum": {"block_n": 512},
    "cox_coord": {"block": 1024},
    "cox_batch": {"block_n": 512, "block_p": 256},
    "lipschitz": {"block_n": 512},
    "survival_curves": {"block_b": 256, "block_g": 128},
    "survival_curves_strat": {"block_g": 128},
}

# shape axes that key a bucket, in display order
SHAPE_AXES: Dict[str, Tuple[str, ...]] = {
    "revcumsum": ("n", "m"),
    "cox_coord": ("n",),
    "cox_batch": ("n", "p"),
    "lipschitz": ("n", "m"),
    "survival_curves": ("b", "g"),
    "survival_curves_strat": ("b", "g"),
}

# config key -> the shape axis it tiles (used to prune candidates that are
# grossly oversized for a bucket; the default config always survives)
BLOCK_AXES: Dict[str, Dict[str, str]] = {
    "revcumsum": {"block_n": "n"},
    "cox_coord": {"block": "n"},
    "cox_batch": {"block_n": "n", "block_p": "p"},
    "lipschitz": {"block_n": "n"},
    "survival_curves": {"block_b": "b", "block_g": "g"},
    "survival_curves_strat": {"block_g": "g"},
}

# candidate grids: small on purpose (autotuning cost is linear in their
# size) and all TPU-tileable (multiples of the (8, 128) f32 tile)
CANDIDATES: Dict[str, List[Dict[str, int]]] = {
    "revcumsum": [{"block_n": b} for b in (256, 512, 1024, 2048)],
    "cox_coord": [{"block": b} for b in (512, 1024, 2048, 4096)],
    "cox_batch": [
        {"block_n": 512, "block_p": 256},
        {"block_n": 1024, "block_p": 256},
        {"block_n": 2048, "block_p": 128},
        {"block_n": 1024, "block_p": 512},
    ],
    "lipschitz": [{"block_n": b} for b in (256, 512, 1024, 2048)],
    "survival_curves": [
        {"block_b": 128, "block_g": 128},
        {"block_b": 256, "block_g": 128},
        {"block_b": 512, "block_g": 128},
        {"block_b": 1024, "block_g": 128},
        {"block_b": 256, "block_g": 256},
        {"block_b": 1024, "block_g": 512},
    ],
    "survival_curves_strat": [{"block_g": b} for b in (128, 256, 512)],
}

# shapes swept by ``benchmarks/run.py --autotune``: the bench_kernels
# shapes plus the default serving curve shapes (engine grid_size=128)
DEFAULT_SWEEP: List[Tuple[str, Dict[str, int]]] = [
    ("revcumsum", {"n": 65536, "m": 128}),
    ("cox_coord", {"n": 65536}),
    ("cox_batch", {"n": 100_000, "p": 64}),
    ("lipschitz", {"n": 65536, "m": 16}),
    ("survival_curves", {"b": 256, "g": 128}),
    ("survival_curves", {"b": 1024, "g": 128}),
]

_KERNEL_FNS = {
    "revcumsum": revcumsum,
    "cox_coord": cox_coord,
    "cox_batch": cox_batch,
    "lipschitz": lipschitz,
    "survival_curves": survival_curves,
    "survival_curves_strat": survival_curves_stratified,
}


# -- buckets and cache keys -------------------------------------------------

def bucket(v: int) -> int:
    """Next power of two >= v (>= 1), same policy as the engine's batches."""
    return 1 << max(int(np.ceil(np.log2(max(int(v), 1)))), 0)


def bucket_key(kernel: str, shape: Dict[str, int],
               backend: Optional[str] = None) -> str:
    backend = backend or jax.default_backend()
    dims = ",".join(f"{a}={bucket(shape[a])}" for a in SHAPE_AXES[kernel])
    return f"{backend}/{kernel}/{dims}"


# -- JSON cache -------------------------------------------------------------

def cache_path() -> str:
    return os.environ.get(CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "tuned_blocks.json")

_LOADED: Dict[str, Dict[str, dict]] = {}   # path -> entries (lazy, per file)


def load_cache(path: Optional[str] = None,
               refresh: bool = False) -> Dict[str, dict]:
    path = path or cache_path()
    if refresh or path not in _LOADED:
        try:
            with open(path) as f:
                data = json.load(f)
            entries = data.get("entries", {}) if isinstance(data, dict) else {}
        except (OSError, ValueError):
            entries = {}
        _LOADED[path] = entries
    return _LOADED[path]


def save_cache(entries: Dict[str, dict], path: Optional[str] = None) -> str:
    path = path or cache_path()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": CACHE_VERSION, "entries": entries}, f,
                  indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    _LOADED[path] = entries
    return path


def lookup_tagged(kernel: str, cache_file: Optional[str] = None,
                  **shape: int) -> Tuple[Dict[str, int], str]:
    """(config, provenance) for ``kernel`` at ``shape`` — the dispatch read.

    Provenance is ``"tuned"`` when the bucket has a cached winner and
    ``"default"`` on the static fallback; ``ops.py`` tags its per-kernel
    dispatch counters with it, so an untuned fleet shows up in metrics
    rather than silently running default blocks. Never times anything.
    """
    entry = load_cache(cache_file).get(bucket_key(kernel, shape))
    if entry and isinstance(entry.get("config"), dict):
        return dict(entry["config"]), "tuned"
    return dict(DEFAULT_CONFIGS[kernel]), "default"


def lookup(kernel: str, cache_file: Optional[str] = None,
           **shape: int) -> Dict[str, int]:
    """Tuned block config (``DEFAULT_CONFIGS`` fallback); see lookup_tagged."""
    return lookup_tagged(kernel, cache_file, **shape)[0]


# -- timing -----------------------------------------------------------------

def _build_inputs(kernel: str, shape: Dict[str, int], seed: int = 0):
    """Random inputs honoring the kernel's contract (sorted/tie-free not
    required: these kernels only assume the precomputed-vector algebra)."""
    rng = np.random.default_rng(seed)
    if kernel == "revcumsum":
        n, m = shape["n"], shape["m"]
        return (jnp.asarray(rng.standard_normal((n, m)), jnp.float32),)
    if kernel == "cox_coord":
        n = shape["n"]
        return (jnp.asarray(rng.standard_normal(n) * 0.3, jnp.float32),
                jnp.asarray(rng.standard_normal(n), jnp.float32),
                jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32)))
    if kernel == "cox_batch":
        n, p = shape["n"], shape["p"]
        x = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
        eta = jnp.asarray(rng.standard_normal(n) * 0.3, jnp.float32)
        d = jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32))
        w = jnp.exp(eta - jnp.max(eta))
        inv_s0 = 1.0 / jax.lax.cumsum(w, axis=0, reverse=True)
        wa = w * jnp.cumsum(d * inv_s0)
        return (x, w, wa - d, wa, d, inv_s0)
    if kernel == "lipschitz":
        n, m = shape["n"], shape["m"]
        return (jnp.asarray(rng.standard_normal((n, m)), jnp.float32),
                jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32)))
    if kernel == "survival_curves":
        b, g = shape["b"], shape["g"]
        return (jnp.asarray(rng.standard_normal(b) * 0.5, jnp.float32),
                jnp.asarray(np.linspace(0.0, 2.0, g), jnp.float32))
    if kernel == "survival_curves_strat":
        b, g = shape["b"], shape["g"]
        s = 8
        h0 = np.cumsum(rng.uniform(0.0, 0.05, size=(s, g)), axis=1)
        return (jnp.asarray(rng.standard_normal(b) * 0.5, jnp.float32),
                jnp.asarray(h0, jnp.float32),
                jnp.asarray(rng.integers(0, s, size=b), jnp.int32))
    raise KeyError(f"unknown kernel {kernel!r}")


def run_config(kernel: str, inputs: tuple, config: Dict[str, int],
               interpret: Optional[bool] = None):
    """One kernel call at an explicit block config (tuning / parity tests)."""
    return _KERNEL_FNS[kernel](*inputs, **config, interpret=interpret)


def _time_call(fn, reps: int = 3) -> float:
    """Mean wall microseconds per call, after a compile/warm-up call."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def candidates_for(kernel: str, shape: Dict[str, int]) -> List[Dict[str, int]]:
    """Candidate grid pruned to the shape bucket (a block dim larger than
    the padded bucket only adds padding); the default always survives so
    the winner is by construction >= as fast as the fixed blocks."""
    axes = BLOCK_AXES[kernel]
    floor = {k: min(c[k] for c in CANDIDATES[kernel]) for k in axes}
    default = DEFAULT_CONFIGS[kernel]
    out: List[Dict[str, int]] = [dict(default)]
    for cfg in CANDIDATES[kernel]:
        if cfg in out:
            continue
        if any(cfg[k] > max(bucket(shape[ax]), floor[k])
               for k, ax in axes.items()):
            continue
        out.append(dict(cfg))
    return out


def _cfg_key(cfg: Dict[str, int]) -> str:
    return ",".join(f"{k}={cfg[k]}" for k in sorted(cfg))


def _register(key: str, entry: dict) -> None:
    from ..analysis import roofline
    roofline.register_tuned(key, entry)


def autotune(kernel: str, shape: Dict[str, int], *,
             cache_file: Optional[str] = None, reps: int = 3,
             force: bool = False, interpret: Optional[bool] = None,
             verbose: bool = False) -> Dict[str, int]:
    """Tune one (kernel, bucket): time candidates, persist + return winner.

    A cached bucket is returned without re-timing unless ``force``.
    """
    path = cache_file or cache_path()
    key = bucket_key(kernel, shape)
    entries = load_cache(path, refresh=True)
    cached = entries.get(key)
    if cached is not None and not force and isinstance(
            cached.get("config"), dict):
        _register(key, cached)
        return dict(cached["config"])

    inputs = _build_inputs(kernel, shape)
    timings: Dict[str, dict] = {}
    # $REPRO_PROFILE_DIR captures the candidate timing as a TensorBoard
    # trace, one capture per (kernel, bucket); no-op when unset
    with obs_profile.maybe_profile(f"autotune/{key}"):
        for cfg in candidates_for(kernel, shape):
            us = _time_call(
                lambda cfg=cfg: run_config(kernel, inputs, cfg, interpret),
                reps=reps)
            timings[_cfg_key(cfg)] = {"config": cfg, "us": us}
            if verbose:
                print(f"[autotune] {key} {_cfg_key(cfg)} {us:.1f}us",
                      flush=True)
    best = min(timings.values(), key=lambda e: e["us"])
    entry = {
        "kernel": kernel,
        "backend": key.split("/", 1)[0],
        "shape": {a: int(shape[a]) for a in SHAPE_AXES[kernel]},
        "config": dict(best["config"]),
        "us": best["us"],
        "default_config": dict(DEFAULT_CONFIGS[kernel]),
        "default_us": timings[_cfg_key(DEFAULT_CONFIGS[kernel])]["us"],
        "candidates": {k: v["us"] for k, v in timings.items()},
        "reps": reps,
    }
    entries[key] = entry
    save_cache(entries, path)
    _register(key, entry)
    obs_events.emit("autotune.winner", key=key, config=best["config"],
                    us=best["us"], default_us=entry["default_us"])
    if verbose:
        print(f"[autotune] {key} winner {_cfg_key(best['config'])} "
              f"({best['us']:.1f}us vs default "
              f"{entry['default_us']:.1f}us)", flush=True)
    return dict(best["config"])


def sweep(shapes: Optional[Sequence[Tuple[str, Dict[str, int]]]] = None,
          **kwargs) -> Dict[str, Dict[str, int]]:
    """Autotune a list of (kernel, shape) pairs; defaults to DEFAULT_SWEEP."""
    winners: Dict[str, Dict[str, int]] = {}
    for kernel, shape in (shapes if shapes is not None else DEFAULT_SWEEP):
        winners[bucket_key(kernel, shape)] = autotune(kernel, shape, **kwargs)
    return winners
