"""Pure-jnp oracles for every Pallas kernel (tie-free path, matching the
kernels' contracts exactly). Tests assert_allclose kernels against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def revcumsum_ref(x: jax.Array) -> jax.Array:
    return jax.lax.cumsum(x.astype(jnp.float32), axis=0,
                          reverse=True).astype(x.dtype)


def cox_coord_ref(eta: jax.Array, x: jax.Array, delta: jax.Array,
                  order: int = 2):
    """(g, h, c3) with risk set R_i = {j >= i} (strictly increasing times)."""
    eta = eta.astype(jnp.float32)
    x = x.astype(jnp.float32)
    delta = delta.astype(jnp.float32)
    w = jnp.exp(eta - jnp.max(eta))
    rc = lambda v: jax.lax.cumsum(v, axis=0, reverse=True)
    s0 = rc(w)
    m1 = rc(w * x) / s0
    m2 = rc(w * x * x) / s0
    g = jnp.sum(delta * (m1 - x))
    h = jnp.sum(delta * (m2 - m1 * m1))
    if order < 3:
        return g, h, jnp.float32(0.0)
    m3 = rc(w * x**3) / s0
    c3 = jnp.sum(delta * (m3 + 2.0 * m1**3 - 3.0 * m2 * m1))
    return g, h, c3


def cox_batch_ref(x: jax.Array, w: jax.Array, r: jax.Array, wa: jax.Array,
                  delta: jax.Array, inv_s0: jax.Array):
    """All-coordinate (grad, hess_diag) from precomputed vectors."""
    x = x.astype(jnp.float32)
    g = x.T @ r.astype(jnp.float32)
    term1 = (x * x).T @ wa.astype(jnp.float32)
    s1 = jax.lax.cumsum(w[:, None].astype(jnp.float32) * x, axis=0,
                        reverse=True)
    m = s1 * inv_s0[:, None].astype(jnp.float32)
    term2 = (delta.astype(jnp.float32)[:, None] * m * m).sum(axis=0)
    return g, term1 - term2


def survival_curves_ref(eta: jax.Array, h0: jax.Array) -> jax.Array:
    """(b, g) S(t_g|x_b) = exp(-H0_g * exp(eta_b)), eta clipped to +/-30."""
    risk = jnp.exp(jnp.clip(eta.astype(jnp.float32), -30.0, 30.0))
    return jnp.exp(-risk[:, None] * h0.astype(jnp.float32)[None, :])


def survival_curves_stratified_ref(eta: jax.Array, h0: jax.Array,
                                   strata: jax.Array) -> jax.Array:
    """(b, g) S = exp(-H0[strata_b, g] * exp(eta_b)); h0 is (s, g)."""
    risk = jnp.exp(jnp.clip(eta.astype(jnp.float32), -30.0, 30.0))
    return jnp.exp(-h0.astype(jnp.float32)[strata] * risk[:, None])


def lipschitz_ref(x: jax.Array, delta: jax.Array):
    """(L2, L3) Theorem-3.4 constants for a time-sorted tie-free panel."""
    import numpy as np

    x = x.astype(jnp.float32)
    smax = jax.lax.associative_scan(jnp.maximum, x[::-1], axis=0)[::-1]
    smin = jax.lax.associative_scan(jnp.minimum, x[::-1], axis=0)[::-1]
    rng = smax - smin
    d = delta.astype(jnp.float32)[:, None]
    l2 = 0.25 * jnp.sum(d * rng * rng, axis=0)
    l3 = jnp.float32(1.0 / (6.0 * np.sqrt(3.0))) * jnp.sum(
        d * rng * rng * rng, axis=0)
    return l2, l3
