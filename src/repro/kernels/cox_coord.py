"""Pallas TPU kernel: fused per-coordinate CPH derivatives (Theorem 3.1).

One coordinate-descent touch needs, for a feature column x and current
linear predictor eta (both time-sorted ascending, *strictly increasing
times* — the tie-free fast path; ops.py falls back to the jnp reference
when ties exist):

    w    = exp(eta - eta_max)
    s_r  = suffix_sum(w * x^r),  r = 0..order+1
    g    = sum delta * (s1/s0 - x)
    h    = sum delta * (s2/s0 - (s1/s0)^2)
    c3   = sum delta * (s3/s0 + 2(s1/s0)^3 - 3(s2/s0)(s1/s0))

On CPU this is 6+ passes over n; here it is one HBM pass: the grid walks
row-blocks of the (nb, bs) reshaped arrays right-to-left, all moments are
formed in VMEM, in-block suffix sums run on the MXU (lower-triangular ones
matmul), and a (k,1) VMEM scratch carries cross-block totals. Outputs are
(1,1) scalars accumulated across grid steps (legal: TPU grids execute
sequentially and output blocks map to the same tile every step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lower_tri(bs: int, dtype=jnp.float32):
    """(P @ L)[., i] = sum_{j >= i} P[., j]  (suffix over the lane axis)."""
    row = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    return (row >= col).astype(dtype)


def _make_kernel(order: int):
    k = order + 2  # moments 0..order+1

    def kernel(eta_max_ref, eta_ref, x_ref, d_ref, g_ref, h_ref, c3_ref,
               carry_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            carry_ref[...] = jnp.zeros_like(carry_ref)
            g_ref[...] = jnp.zeros_like(g_ref)
            h_ref[...] = jnp.zeros_like(h_ref)
            c3_ref[...] = jnp.zeros_like(c3_ref)

        e = eta_ref[...].astype(jnp.float32)   # (1, bs)
        x = x_ref[...].astype(jnp.float32)
        d = d_ref[...].astype(jnp.float32)
        w = jnp.exp(e - eta_max_ref[0, 0])

        rows = [w]
        for _ in range(k - 1):
            rows.append(rows[-1] * x)
        p = jnp.concatenate(rows, axis=0)       # (k, bs)
        bs = p.shape[1]
        suff = jax.lax.dot_general(
            p, _lower_tri(bs), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + carry_ref[...]
        # padded tail rows have w == 0 -> s0 == 0; clamp so the delta-masked
        # (d == 0) contributions stay finite instead of 0 * nan
        s0 = jnp.maximum(suff[0:1], 1e-30)
        m1 = suff[1:2] / s0
        m2 = suff[2:3] / s0
        g_ref[...] += jnp.sum(d * (m1 - x), axis=1, keepdims=True)
        h_ref[...] += jnp.sum(d * (m2 - m1 * m1), axis=1, keepdims=True)
        if order >= 3:
            m3 = suff[3:4] / s0
            c3_ref[...] += jnp.sum(
                d * (m3 + 2.0 * m1**3 - 3.0 * m2 * m1), axis=1, keepdims=True)
        carry_ref[...] = carry_ref[...] + jnp.sum(p, axis=1, keepdims=True)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("order", "block", "interpret"))
def _cox_coord_jit(eta: jax.Array, x: jax.Array, delta: jax.Array,
                   order: int, block: int, interpret: bool):
    n = eta.shape[0]
    nb = pl.cdiv(n, block)
    pad = nb * block - n

    def prep(v, fill=0.0):
        v = jnp.pad(v, (0, pad), constant_values=fill) if pad else v
        return v.reshape(nb, block)

    # pad eta with -inf-ish so padded w == 0 (exp(-1e30 - max) underflows)
    eta_max = jnp.max(eta).reshape(1, 1).astype(jnp.float32)
    eta_p = prep(eta, fill=-1e30)
    x_p = prep(x)
    d_p = prep(delta)
    k = order + 2

    scalar = jax.ShapeDtypeStruct((1, 1), jnp.float32)
    g, h, c3 = pl.pallas_call(
        _make_kernel(order),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block), lambda i: (nb - 1 - i, 0)),
            pl.BlockSpec((1, block), lambda i: (nb - 1 - i, 0)),
            pl.BlockSpec((1, block), lambda i: (nb - 1 - i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[scalar, scalar, scalar],
        scratch_shapes=[pltpu.VMEM((k, 1), jnp.float32)],
        interpret=interpret,
    )(eta_max, eta_p, x_p, d_p)
    return g[0, 0], h[0, 0], c3[0, 0]


def cox_coord(eta: jax.Array, x: jax.Array, delta: jax.Array,
              order: int = 2, block: int = 1024,
              interpret: bool | None = None):
    """Fused (g, h[, c3]) for one coordinate; n-length 1-D inputs, no ties.

    ``interpret=None`` resolves backend-aware: native on TPU, interpret
    mode elsewhere. Pass an explicit bool to override (tests).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _cox_coord_jit(eta, x, delta, order=order, block=block,
                          interpret=interpret)
