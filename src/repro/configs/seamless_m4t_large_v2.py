"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal; the audio
frontend is a STUB: input_specs provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256206, head_dim=64, frontend="audio",
)
