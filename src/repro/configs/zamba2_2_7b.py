"""zamba2-2.7b [hybrid] — Mamba2 backbone + one shared transformer block
applied every 6 layers. [arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
    head_dim=80, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=64, shared_attn_every=6, supports_long_context=True,
)
