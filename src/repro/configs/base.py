"""Config dataclasses: architectures, shapes, mesh, training."""
from __future__ import annotations

import dataclasses
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 1e6
    sliding_window: int = 0         # >0: SWA width on every layer
    local_global_ratio: int = 0     # gemma3: 5 local : 1 global
    local_window: int = 1024
    n_experts: int = 0
    n_experts_per_tok: int = 0
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    shared_attn_every: int = 0      # zamba2: shared attn block period
    encoder_layers: int = 0         # >0 -> encoder-decoder
    mrope_sections: Tuple[int, ...] = ()
    rms_eps: float = 1e-6
    frontend: str = "none"          # none | audio | vision (stubbed embeds)
    tie_embeddings: bool = False
    q_chunk: int = 1024
    kv_chunk: int = 1024
    scan_unroll: int = 1   # >1 only in dry-run accounting probes
    dtype: str = "bfloat16"
    # long_500k applicability (sub-quadratic serving path exists)
    supports_long_context: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to 256 so logits shard 16-way cleanly."""
        return _round_up(self.vocab_size, 256)

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced variant for smoke tests (same family/topology)."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    microbatch: int = 0             # 0 = no gradient accumulation
    remat: object = True   # False | True/"nothing" | "dots"
    moe_aux_weight: float = 0.01
    # distributed-optimization toggles (§Perf / fault_tolerance)
    grad_compression: str = "none"  # none | int8
    zero1: bool = True              # shard optimizer state over data axis
