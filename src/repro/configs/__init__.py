"""Architecture registry: --arch <id> resolves here."""
from .base import SHAPES, ModelConfig, ShapeSpec, TrainConfig  # noqa: F401

from . import (deepseek_67b, gemma3_12b, mamba2_130m, mixtral_8x22b,
               mixtral_8x7b, qwen1_5_4b, qwen2_5_3b, qwen2_vl_7b,
               seamless_m4t_large_v2, zamba2_2_7b)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_5_3b, qwen1_5_4b, gemma3_12b, deepseek_67b,
              seamless_m4t_large_v2, mixtral_8x7b, mixtral_8x22b,
              qwen2_vl_7b, mamba2_130m, zamba2_2_7b)
}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def applicable_shapes(cfg: ModelConfig):
    """The 4 shape cells for this arch, with long_500k gated on a
    sub-quadratic serving path (DESIGN.md §long_500k skips)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            out.append((s, "skipped: pure full-attention at 512k"))
        else:
            out.append((s, None))
    return out


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Small same-family variant for CPU smoke tests."""
    kw = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=512,
              n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
              head_dim=16, q_chunk=32, kv_chunk=32, dtype="float32")
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, shared_attn_every=2)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2)
    if cfg.n_experts:
        kw.update(n_experts=4)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    if cfg.local_global_ratio:
        kw.update(local_global_ratio=1, local_window=8)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 2, 2))
    return cfg.scaled(**kw)
