#!/usr/bin/env bash
# Runtime-tuned launcher (the SNIPPETS.md / HomebrewNLP recipe).
#
# Applies the same policy as src/repro/launch/runtime.py plus the one
# thing Python cannot do for itself: preloading tcmalloc. Existing env
# values always win (every export below is a default, not an override).
#
#   scripts/launch.sh -m benchmarks.run --smoke
#   scripts/launch.sh -m benchmarks.run --only kernels,serving --autotune --json BENCH_6.json
#   scripts/launch.sh examples/serve_risk_api.py
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

# faster malloc, when the container ships it
if [ -z "${LD_PRELOAD:-}" ]; then
  for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
            /usr/lib/libtcmalloc.so.4 \
            /usr/lib/libtcmalloc_minimal.so.4; do
    if [ -f "$so" ]; then
      export LD_PRELOAD="$so"
      break
    fi
  done
fi

export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"      # no TF/XLA chatter
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}" # f32 dtype policy
export XLA_FLAGS="${XLA_FLAGS:-}"                             # deployment flags slot
export REPRO_TUNE_CACHE="${REPRO_TUNE_CACHE:-$ROOT/benchmarks/tuned_blocks.json}"
export PYTHONPATH="$ROOT/src${PYTHONPATH:+:$PYTHONPATH}"

exec /usr/bin/env python "$@"
