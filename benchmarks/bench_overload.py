"""Open-loop overload benchmark: Poisson/burst traffic past saturation.

Closed-loop drains (bench_serving.py) can never overload the service —
the submitter waits for the server, so the queue self-limits. Real
traffic doesn't: arrivals follow their own clock. This bench drives
``RiskService`` with an *open-loop* generator (seeded Poisson
inter-arrivals, optional bursts) at multiples of the measured saturation
capacity and records what the admission-control layer does about it:

  * ``overload/capacity``        — closed-loop saturation throughput
  * ``overload/p99_high@Mx``     — HIGH-priority p99 at offered load M*cap
    (bounded past saturation is the acceptance criterion: shed-low-first
    eviction + server-side deadlines keep the HIGH queue short)
  * ``overload/shed@Mx``         — shed fraction (queue-full rejects +
    evictions + deadline drops) of offered load
  * ``overload/silent_loss``     — submitted rids with *no* terminal
    outcome across every run; must be 0
  * ``overload/burst``           — p99_high under periodic bursts riding
    a sub-saturation Poisson base
  * ``overload/hot_swap_dropped``/``..._spike`` — a ``ModelRegistry``
    rollout under live load: dropped must be 0; spike is the p99 of
    requests submitted within the swap window vs steady state

Committed as ``BENCH_9.json`` (via ``run.py --only overload --json``);
``run.py --smoke`` re-runs a tiny version and gates on bounded
p99_high@2x, zero silent loss, and a zero-drop hot swap.

The served model is deliberately heavy (wide p, long curve grid, curves
returned) so saturation sits at a rate one Python generator thread can
comfortably exceed — the bench measures queueing policy, not submit().
"""
import time

import numpy as np

from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.serving import (ModelRegistry, Priority, QueueFull, RiskService,
                           ScoringEngine, fit_survival_model)


def _models(p, grid, seed=0):
    """Two artifacts (champion + retrain candidate) on the same schema."""
    x, t, delta, beta_star = make_correlated_survival(
        SyntheticSpec(n=512, p=p, k=8, rho=0.5, seed=seed, censor_scale=3.0))
    grid_t = np.linspace(float(t.min()), float(t.max()), grid,
                         dtype=np.float32)
    m1 = fit_survival_model(x, t, delta, beta_star, time_grid=grid_t)
    m2 = fit_survival_model(x, t, delta,
                            (beta_star * 0.9).astype(np.float32),
                            time_grid=grid_t)
    return x, m1, m2


def _service(model, *, max_batch, max_queue, return_curves=True):
    eng = ScoringEngine(model, use_sparse=False)
    svc = RiskService(eng, max_batch=max_batch, max_queue=max_queue,
                      return_curves=return_curves, result_ttl_s=300.0)
    # warm the full pow-2 bucket ladder: a cold mid-ladder bucket would
    # bill a jit compile to some unlucky request's latency
    ladder = tuple(1 << i for i in range((max_batch - 1).bit_length() + 1))
    eng.prewarm(ladder, kinds=(
        "score_curves" if return_curves else "score",))
    return svc


def estimate_capacity(svc, feats, n_req):
    """Closed-loop saturation: submit n_req, drain flat out."""
    t0 = time.perf_counter()
    for i in range(n_req):
        svc.submit(feats[i % len(feats)])
    svc.drain()
    return n_req / (time.perf_counter() - t0)


def _arrivals(rps, duration_s, seed, burst=None):
    """Seeded Poisson arrival offsets; ``burst=(every_s, n)`` adds n
    simultaneous arrivals every every_s seconds."""
    rng = np.random.default_rng(seed)
    n = max(int(rps * duration_s * 2), 16)
    ts = np.cumsum(rng.exponential(1.0 / rps, size=n))
    ts = ts[ts < duration_s]
    if burst is not None:
        every_s, bn = burst
        spikes = np.repeat(np.arange(every_s, duration_s, every_s), bn)
        ts = np.sort(np.concatenate([ts, spikes]))
    return ts


def open_loop(svc, feats, *, rps, duration_s, frac_high=0.25,
              deadline_low_s=0.25, deadline_high_s=None, seed=0,
              burst=None, mid_run=None):
    """Drive the service open-loop; returns per-outcome accounting.

    Arrivals keep their own clock: a backlogged schedule submits in a
    burst rather than waiting for the server (that's the point).
    ``mid_run`` is an optional callback fired once past duration/2 on
    its own thread — traffic keeps flowing while it runs (the hot-swap
    hook); its trigger wall-time is recorded.
    """
    import threading
    arrivals = _arrivals(rps, duration_s, seed, burst)
    rng = np.random.default_rng(seed + 1)
    prios = np.where(rng.random(len(arrivals)) < frac_high,
                     int(Priority.HIGH), int(Priority.LOW))
    svc.start()
    submitted = []           # (rid, priority, t_submit_rel)
    rejected = {Priority.HIGH: 0, Priority.LOW: 0}
    t_mid = None
    mid_thread = None
    t0 = time.perf_counter()
    for t_arr, prio in zip(arrivals, prios):
        if mid_run is not None and t_mid is None and t_arr >= duration_s / 2:
            t_mid = time.perf_counter() - t0
            mid_thread = threading.Thread(target=mid_run, daemon=True)
            mid_thread.start()
        delay = t_arr - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        prio = Priority(int(prio))
        deadline = (deadline_high_s if prio == Priority.HIGH
                    else deadline_low_s)
        f = feats[rng.integers(0, len(feats))]
        try:
            rid = svc.submit(f, priority=prio, deadline_s=deadline)
            submitted.append((rid, prio, t_arr))
        except QueueFull:
            rejected[prio] += 1
    if mid_thread is not None:
        mid_thread.join(60.0)
    # let the backlog resolve (shed/expire/score), then stop the loop
    deadline_drain = time.perf_counter() + 30.0
    while svc.stats()["queue_depth"] and time.perf_counter() < deadline_drain:
        time.sleep(0.005)
    svc.stop()
    svc.drain()              # flush anything left between stop and empty

    out = {"offered": len(arrivals), "rejected": dict(rejected),
           "t_mid": t_mid, "duration_s": duration_s,
           "lat_ms": {Priority.HIGH: [], Priority.LOW: []},
           "t_sub": {Priority.HIGH: [], Priority.LOW: []},
           "shed": 0, "expired": 0, "errors": 0, "lost": 0}
    for rid, prio, t_arr in submitted:
        resp = svc.result(rid)
        if resp is None:
            out["lost"] += 1
        elif resp.ok:
            out["lat_ms"][prio].append(resp.latency_s * 1e3)
            out["t_sub"][prio].append(t_arr)
        elif resp.error == "shed":
            out["shed"] += 1
        elif resp.error == "deadline_exceeded":
            out["expired"] += 1
        else:
            out["errors"] += 1
    return out


def _p99(v):
    return float(np.percentile(v, 99)) if len(v) else 0.0


def _shed_frac(res):
    lost_to_load = (sum(res["rejected"].values()) + res["shed"]
                    + res["expired"])
    return lost_to_load / max(res["offered"], 1)


def run(smoke: bool = False):
    rows = []
    # heavy-ish model: saturation low enough that one generator thread
    # can offer 2x+ while Python submit overhead stays negligible
    p, grid = (128, 128) if smoke else (256, 256)
    max_batch = 8 if smoke else 16
    max_queue = 8 * max_batch
    dur = 1.0 if smoke else 3.0
    feats_n = 256
    x, model, model2 = _models(p, grid)
    rng = np.random.default_rng(7)
    feats = rng.standard_normal((feats_n, p)).astype(np.float32)

    svc = _service(model, max_batch=max_batch, max_queue=None)
    cap = estimate_capacity(svc, feats, 128 if smoke else 512)
    rows.append(("overload/capacity", 1e6 / cap,
                 f"closed_loop_reqs_per_s={cap:.0f}", cap))

    silent_loss = 0
    deadline_low = 0.25
    for mult in ((0.5, 2.0) if smoke else (0.5, 1.0, 2.0, 4.0)):
        svc = _service(model, max_batch=max_batch, max_queue=max_queue)
        res = open_loop(svc, feats, rps=mult * cap, duration_s=dur,
                        deadline_low_s=deadline_low, seed=int(mult * 10))
        silent_loss += res["lost"] + res["errors"]
        p99h = _p99(res["lat_ms"][Priority.HIGH])
        p99l = _p99(res["lat_ms"][Priority.LOW])
        tag = f"{mult:g}x"
        rows.append((f"overload/p99_high@{tag}", p99h * 1e3,
                     f"p99_low_ms={p99l:.1f} offered={res['offered']} "
                     f"served_high={len(res['lat_ms'][Priority.HIGH])}",
                     p99h))
        rows.append((f"overload/shed@{tag}", 0.0,
                     f"rejected={sum(res['rejected'].values())} "
                     f"evicted={res['shed']} expired={res['expired']}",
                     _shed_frac(res)))

    # bursts riding a sub-saturation base: every 0.25s, 4*max_batch at once
    svc = _service(model, max_batch=max_batch, max_queue=max_queue)
    res = open_loop(svc, feats, rps=0.5 * cap, duration_s=dur,
                    deadline_low_s=deadline_low, seed=42,
                    burst=(0.25, 4 * max_batch))
    silent_loss += res["lost"] + res["errors"]
    p99h = _p99(res["lat_ms"][Priority.HIGH])
    rows.append(("overload/burst", p99h * 1e3,
                 f"base=0.5x burst={4 * max_batch}req/250ms "
                 f"shed_frac={_shed_frac(res):.2f}", p99h))

    # hot swap under load: registry rollout at mid-run, nothing dropped
    svc = _service(model, max_batch=max_batch, max_queue=None)
    reg = ModelRegistry(svc)
    reg.load("champ", model)
    reg.swap("champ")
    swap_gen = []
    res = open_loop(
        svc, feats, rps=0.4 * cap, duration_s=dur, frac_high=0.25,
        deadline_low_s=None, seed=5,
        mid_run=lambda: swap_gen.append(reg.rollout("retrain", model2)))
    dropped = (res["lost"] + res["errors"] + res["shed"] + res["expired"]
               + sum(res["rejected"].values()))
    lat_all = res["lat_ms"][Priority.HIGH] + res["lat_ms"][Priority.LOW]
    t_all = res["t_sub"][Priority.HIGH] + res["t_sub"][Priority.LOW]
    lat_all, t_all = np.asarray(lat_all), np.asarray(t_all)
    t_mid = res["t_mid"] if res["t_mid"] is not None else dur / 2
    win = (t_all >= t_mid - 0.1) & (t_all <= t_mid + 0.4)
    p99_win = _p99(lat_all[win])
    p99_steady = _p99(lat_all[~win]) or 1e-9
    rows.append(("overload/hot_swap_dropped", 0.0,
                 f"gen={swap_gen[0] if swap_gen else 'none'} "
                 f"served={len(lat_all)} live={reg.status()['live']}",
                 float(dropped)))
    rows.append(("overload/hot_swap_spike", p99_win * 1e3,
                 f"p99_swap_window_ms={p99_win:.1f} "
                 f"p99_steady_ms={p99_steady:.1f} "
                 f"x{p99_win / p99_steady:.1f}", p99_win / p99_steady))

    rows.append(("overload/silent_loss", 0.0,
                 "submitted rids with no terminal outcome (must be 0)",
                 float(silent_loss)))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
