import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf extra cell E: MoE expert parallelism vs tensor parallelism
(mixtral-8x7b train_4k).

Baseline (grid): experts replicated across `model`, each expert's FFN
hidden dim TP-sharded 16-way. EP variant: the 256 chips are re-arranged as
(data=16, expert=8, tp=2) — expert weights shard their expert dim over
`expert` and FFN dim 2-way over `tp`; the scatter dispatch then implies an
all-to-all of tokens to expert-owning shards instead of replicating every
expert's weights 16x.

Napkin: TP layout moves activations through 2 all-reduces per MoE layer
(bf16 (tokens_local, d) = 16*4096*4096*2B = 0.5GB each) but zero expert
weight traffic; EP moves each routed token twice over the all-to-all
((tokens_local * 2/8 per peer) ~ 0.25GB) — EP should cut the MoE-layer
collective bytes roughly in half and drop per-chip expert weight memory 8x.
"""

import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import roofline as rl                    # noqa: E402
from repro.configs import SHAPES, TrainConfig, get_config    # noqa: E402
from repro.launch import dryrun                              # noqa: E402
from repro.models import build_model                         # noqa: E402
from repro.train import optimizer as opt_lib                 # noqa: E402
from repro.train.trainer import TrainState, make_train_step  # noqa: E402
from benchmarks.perf_iterations import log, measure          # noqa: E402


def make_ep_mesh():
    return jax.make_mesh(
        (16, 8, 2), ("data", "expert", "tp"),
        axis_types=(jax.sharding.AxisType.Auto,) * 3)


def lower_ep(cfg, shape):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_ep_mesh()
    model = build_model(cfg)

    def pspec_for(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        name = names[-1] if names else ""
        if name in ("w_gate", "w_up", "w_down") and len(leaf.shape) == 4:
            # (L, E, a, b): experts over `expert`, last dim over `tp`
            return NamedSharding(
                mesh, P(None, "expert", None,
                        "tp" if leaf.shape[-1] % 2 == 0 else None))
        if name == "embed":
            return NamedSharding(mesh, P(("expert", "tp"), None))
        if len(leaf.shape) >= 2 and leaf.shape[-1] % 16 == 0 \
                and leaf.shape[-1] >= 1024:
            spec = [None] * len(leaf.shape)
            spec[-1] = ("expert", "tp")
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    with jax.set_mesh(mesh):
        pshape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        params = jax.tree_util.tree_map_with_path(
            lambda p, l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                              sharding=pspec_for(p, l)),
            pshape)
        opt_shape = jax.eval_shape(opt_lib.init_opt_state, params)
        opt = jax.tree_util.tree_map_with_path(
            lambda p, l: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=pspec_for(p[1:], l)), opt_shape)
        state = TrainState(params=params, opt=opt)
        bsh = NamedSharding(mesh, P("data", None))
        batch = {k: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=bsh)
                 for k, l in model.make_input_specs(shape).items()}
        step_fn = make_train_step(model, TrainConfig())
        return jax.jit(step_fn, donate_argnums=(0,)).lower(state, batch)


def terms_ep(cfg, shape):
    probes = {}
    for u in (1, 2):
        cm = lower_ep(dryrun.analysis_config(cfg, shape, u), shape).compile()
        ca = cm.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        pc = rl.parse_collectives(cm.as_text())
        probes[u] = (float(ca.get("flops", 0)),
                     float(ca.get("bytes accessed", 0)), pc.moved_bytes)
    units = cfg.n_layers
    f, b, c = (probes[1][i] + (units - 1) * (probes[2][i] - probes[1][i])
               for i in range(3))
    return {"flops": f, "bytes": b, "coll": c,
            "compute_s": f / rl.PEAK_FLOPS, "memory_s": b / rl.HBM_BW,
            "collective_s": c / rl.ICI_BW}


def main():
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    lw = lower_ep(cfg, shape)
    rec = measure(lw)
    rec["terms"] = terms_ep(cfg, shape)
    log("mixtral-8x7b/train_4k/E1_expert_parallel", rec)


if __name__ == "__main__":
    main()
