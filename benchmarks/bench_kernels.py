"""Kernel microbenchmarks (Cor. 3.3's O(n) machinery).

On this container Pallas executes in interpret mode, so the `pallas_*`
rows measure the correctness path, not TPU performance; the `xla_*` rows
(same math through jnp/XLA-CPU) are the meaningful CPU timings and the
scaling column (derived) demonstrates the O(n) claim."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cox
from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    ref_coord = jax.jit(ref.cox_coord_ref)
    scaling = {}
    for n in (10_000, 100_000, 1_000_000):
        eta = jnp.asarray(rng.standard_normal(n) * 0.3, jnp.float32)
        xl = jnp.asarray(rng.standard_normal(n), jnp.float32)
        d = jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32))
        us = _time(ref_coord, eta, xl, d)
        scaling[n] = us
        rows.append((f"kernels/xla_cox_coord/n={n}", us,
                     f"per_sample_ns={us * 1e3 / n:.2f}"))
    # O(n) check: 100x n -> ~100x time (not n^2's 10000x)
    ratio = scaling[1_000_000] / scaling[10_000]
    rows.append(("kernels/xla_cox_coord/linearity", 0.0,
                 f"t(1M)/t(10k)={ratio:.0f} (O(n) ~ 100)"))

    n, p = 100_000, 64
    x = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    eta = jnp.asarray(rng.standard_normal(n) * 0.3, jnp.float32)
    d = jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32))
    batch = jax.jit(lambda e, xx, dd: ops.cox_batch_grad_hess(e, xx, dd))
    rows.append((f"kernels/pallas_cox_batch_interp/n={n},p={p}",
                 _time(batch, eta, x, d, reps=2), "interpret-mode"))
    n = 65536
    v = jnp.asarray(rng.standard_normal((n, 128)), jnp.float32)
    rows.append((f"kernels/pallas_revcumsum_interp/n={n},m=128",
                 _time(ops.revcumsum, v, reps=2), "interpret-mode"))
    coord = jax.jit(lambda e, xx, dd: ops.cox_coord_grad_hess(e, xx, dd))
    eta1 = jnp.asarray(rng.standard_normal(n) * 0.3, jnp.float32)
    x1 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    d1 = jnp.asarray((rng.uniform(size=n) < 0.7).astype(np.float32))
    rows.append((f"kernels/pallas_cox_coord_interp/n={n}",
                 _time(coord, eta1, x1, d1, reps=2), "interpret-mode"))
    m = 16
    xl = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    rows.append((f"kernels/pallas_lipschitz_interp/n={n},m={m}",
                 _time(ops.lipschitz_constants, xl, d1, reps=2),
                 "interpret-mode"))
    b, g = 1024, 128
    etac = jnp.asarray(rng.standard_normal(b) * 0.5, jnp.float32)
    h0 = jnp.asarray(np.linspace(0.0, 2.0, g), jnp.float32)
    rows.append((f"kernels/pallas_survival_curves_interp/b={b},g={g}",
                 _time(ops.survival_curves, etac, h0), "interpret-mode"))
    return rows
