"""Deep-survival benchmark: FastCPH-style backbone + paper-solver head.

Rows are (name, us_per_call, derived[, value]):
  * deep/train            — us per train step under the exact CPH
                            objective (post-compile); value = steps/s
  * deep/refit            — beam-search sparse refit on frozen pooled
                            features; value = seconds
  * deep/cindex_deep      — held-out c-index of the backbone risk head
  * deep/cindex_sparse    — c-index of the k-sparse refit head (the
                            interpretable model the artifact serves)
  * deep/cindex_linear    — linear CPH on raw bag-of-token frequencies,
                            fit with the same solver family: what the
                            paper's machinery achieves *without* the
                            backbone (the deep-vs-linear comparison)
  * deep/served_match     — 1.0 when the exported artifact, rolled out
                            through ModelRegistry into RiskService,
                            returns the sparse head's risks (rtol 1e-4)

The linear baseline sees the same observations as the refit: per-sequence
token-frequency features, so the comparison isolates what representation
learning adds over the raw featurization.
"""
import os
import tempfile
import time

import numpy as np

import jax

from repro.core import cox, solvers
from repro.data.pipeline import SurvivalTextStream
from repro.models import build_model
from repro.serving import ModelRegistry, RiskService
from repro.survival import deep
from repro.survival.metrics import cindex
from repro.train.trainer import make_train_step
from repro.configs.base import TrainConfig


def _token_frequency_features(stream, cfg, start_step, n_batches):
    """(n, vocab) per-sequence token histograms — the raw featurization a
    linear CPH gets when no backbone learns the representation."""
    feats, times, events = [], [], []
    for step in range(start_step, start_step + n_batches):
        b = stream.batch_for_step(step)
        counts = np.stack([np.bincount(row, minlength=cfg.vocab_size)
                           for row in b["tokens"]]).astype(np.float32)
        feats.append(counts / b["tokens"].shape[1])
        times.append(b["time"])
        events.append(b["event"])
    return (np.concatenate(feats), np.concatenate(times),
            np.concatenate(events))


def _served_risks(artifact, features):
    """Roll the artifact through registry -> service; return served risks."""
    with tempfile.TemporaryDirectory(prefix="bench_deep_") as td:
        path = os.path.join(td, "artifact")
        artifact.save(path)
        svc = RiskService(None, max_batch=16)
        reg = ModelRegistry(svc, prewarm_batches=(1, 16))
        reg.rollout("bench_deep", path)
        svc.start()
        try:
            rids = [svc.submit(f) for f in features]
            return np.array([svc.wait(r).risk for r in rids])
        finally:
            svc.stop()


def run(smoke: bool = False):
    rows = []
    dcfg = deep.DeepSurvivalConfig(
        steps=12 if smoke else 120, batch=16 if smoke else 32,
        seq=20 if smoke else 48, k=4 if smoke else 8,
        refit_batches=2 if smoke else 4,
        warmup_steps=4 if smoke else 20, log_every=0)
    cfg = deep.model_config(dcfg)
    model = build_model(cfg)

    # -- train: time steady-state steps (first step pays the jit compile) --
    stream = SurvivalTextStream(cfg.vocab_size, dcfg.seq, dcfg.batch,
                                seed=dcfg.seed)
    state = deep.init_state(model, dcfg.seed)
    tcfg = TrainConfig(learning_rate=dcfg.learning_rate,
                       warmup_steps=dcfg.warmup_steps,
                       total_steps=dcfg.steps)
    step_fn = jax.jit(make_train_step(model, tcfg, objective="cox"))
    state, m = step_fn(state, stream.batch_for_step(0))   # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for step in range(1, dcfg.steps):
        state, m = step_fn(state, stream.batch_for_step(step))
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    steps_per_s = (dcfg.steps - 1) / dt
    rows.append(("deep/train", dt / (dcfg.steps - 1) * 1e6,
                 f"steps_per_s={steps_per_s:.2f} arch={cfg.name} "
                 f"batch={dcfg.batch}", steps_per_s))

    # -- refit: the paper's beam-search CD on frozen pooled features -------
    held = deep.collect_features(model, state, stream, dcfg.steps,
                                 dcfg.refit_batches)
    t0 = time.perf_counter()
    beam, beta, artifact = deep.refit_and_export(
        held["features"], held["time"], held["event"],
        k=dcfg.k, beam_width=dcfg.beam_width, grid_size=dcfg.grid_size)
    dt_refit = time.perf_counter() - t0
    nnz = int((np.abs(beta) > 1e-8).sum())
    rows.append(("deep/refit", dt_refit * 1e6,
                 f"k={dcfg.k} nnz={nnz} n={len(held['time'])} "
                 f"p={cfg.d_model}", dt_refit))

    # -- quality: deep head vs sparse refit vs raw-feature linear CPH ------
    ci_deep = cindex(held["time"], held["event"], held["risk_deep"])
    ci_sparse = cindex(held["time"], held["event"],
                       held["features"] @ beta)
    xf, tf_, ef = _token_frequency_features(stream, cfg, dcfg.steps,
                                            dcfg.refit_batches)
    lin = solvers.fit_cd_tol(cox.prepare(xf, tf_, ef), 0.0, 0.1)
    ci_linear = cindex(tf_, ef, xf @ np.asarray(lin.beta))
    rows.append(("deep/cindex_deep", 0.0,
                 f"heldout_batches={dcfg.refit_batches}", float(ci_deep)))
    rows.append(("deep/cindex_sparse", 0.0, f"nnz={nnz}",
                 float(ci_sparse)))
    rows.append(("deep/cindex_linear", 0.0,
                 f"p={cfg.vocab_size} (token frequencies)",
                 float(ci_linear)))

    # -- serving: artifact -> registry -> RiskService must match ----------
    served = _served_risks(artifact, held["features"][:16])
    expect = np.exp(np.clip(held["features"][:16] @ beta, -30.0, 30.0))
    match = float(np.allclose(served, expect, rtol=1e-4))
    rows.append(("deep/served_match", 0.0,
                 f"requests=16 registry_rollout=1", match))
    return rows
