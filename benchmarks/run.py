"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. A full run on the CPU container
takes a few minutes; individual benches: ``--only efficiency`` etc.

``--smoke`` is the CI guard: it runs the serving-path test files through
the tier-1 pytest entry point and then the serving benchmark at tiny
shapes, so regressions in the jit-cache bucketing or the scoring kernels
are caught in well under a minute.
"""
import argparse
import os
import subprocess
import sys


def _smoke() -> int:
    """Tier-1 pytest on the serving path + tiny-shape serving bench."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    tests = [os.path.join(root, "tests", f)
             for f in ("test_serving.py", "test_kernels.py")]
    print("[smoke] tier-1:", "python -m pytest -x -q", *tests, flush=True)
    rc = subprocess.call([sys.executable, "-m", "pytest", "-x", "-q",
                          *tests], env=env, cwd=root)
    if rc != 0:
        print("[smoke] FAILED: tier-1 tests")
        return rc
    from . import bench_serving
    print("name,us_per_call,derived")
    speedup_ok = False
    for name, us, derived in bench_serving.run(smoke=True):
        print(f"{name},{us:.1f},{derived}", flush=True)
        if name == "serving/batch_speedup":
            speedup_ok = float(derived.split()[0].lstrip("x")) > 1.0
    if not speedup_ok:
        print("[smoke] FAILED: batched serving slower than naive loop")
        return 1
    print("[smoke] OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "efficiency", "selection_f1",
                             "selection_real", "kernels", "serving"])
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI guard: serving tests + tiny benches")
    args = ap.parse_args()

    if args.smoke:
        sys.exit(_smoke())

    from . import (bench_efficiency, bench_kernels, bench_selection_f1,
                   bench_selection_real, bench_serving)
    benches = {
        "efficiency": bench_efficiency.run,       # paper Fig. 1 + App. D.1
        "selection_f1": bench_selection_f1.run,   # paper Fig. 2
        "selection_real": bench_selection_real.run,  # paper Figs. 3/4
        "kernels": bench_kernels.run,             # Cor. 3.3 machinery
        "serving": bench_serving.run,             # inference subsystem
    }
    print("name,us_per_call,derived")
    for key, fn in benches.items():
        if args.only not in ("all", key):
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
