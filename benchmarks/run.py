"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. A full run on the CPU container
takes a few minutes; individual benches: ``--only efficiency`` etc.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "efficiency", "selection_f1",
                             "selection_real", "kernels"])
    args = ap.parse_args()

    from . import (bench_efficiency, bench_kernels, bench_selection_f1,
                   bench_selection_real)
    benches = {
        "efficiency": bench_efficiency.run,       # paper Fig. 1 + App. D.1
        "selection_f1": bench_selection_f1.run,   # paper Fig. 2
        "selection_real": bench_selection_real.run,  # paper Figs. 3/4
        "kernels": bench_kernels.run,             # Cor. 3.3 machinery
    }
    print("name,us_per_call,derived")
    for key, fn in benches.items():
        if args.only not in ("all", key):
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
