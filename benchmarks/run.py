"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes structured records (the committed ``BENCH_*.json`` trajectory
artifacts) of the form::

    {bench, name, us_per_call, derived, [value,] backend, tuned_blocks,
     git_rev}

``--autotune`` runs the kernel block-size sweep first (winners persist to
``$REPRO_TUNE_CACHE``, default ``benchmarks/tuned_blocks.json``, and every
subsequent kernel dispatch uses them). ``--only`` takes a comma-separated
subset, e.g. ``--only kernels,serving``.

``--json`` additionally appends a ``telemetry/metrics_snapshot`` record:
the full ``repro.obs`` registry snapshot (serving/kernel counters the
benches accumulated, plus an instrumented convergence smoke fit), so
each ``BENCH_*.json`` carries convergence-iteration counts and stage
histograms alongside timings.

``--smoke`` is the CI guard: tier-1 pytest on the serving/kernels/autotune
path, a tiny autotune sweep into a throwaway cache, the serving benchmark
at tiny shapes with schema validation of its records, a regression
gate on ``serving/batch_speedup`` against the committed ``BENCH_*.json``
baseline when one exists, a telemetry gate — the embedded metrics
snapshot must validate against its schema and the instrumented smoke fit
must record **zero monotonicity violations** — plus the PR-8 scale gates:
a tiny ``fit_stream`` (zero violations on the live counter), a 2-shard
host-mesh scoring parity check (subprocess, bit-identical to unsharded),
and schema validation of the committed ``BENCH_8.json`` when present.
The PR-9 robustness gates ride along: a tiny open-loop overload run
(HIGH-priority p99 must stay bounded at 2x saturation, a live hot swap
must drop nothing, every submitted request must reach a terminal
outcome) and schema + zero-drop validation of the committed
``BENCH_9.json`` when present. The PR-10 deep-survival gate closes the
loop through the revived model zoo: a tiny backbone trains under the
exact CPH objective, the beam-search refit head exports as a serving
artifact, and that artifact must score through ModelRegistry/RiskService
with exactly the sparse head's risks (plus schema + headline validation
of the committed ``BENCH_10.json``).

Runnable both as ``python -m benchmarks.run`` (with ``PYTHONPATH=src``)
and directly as ``python benchmarks/run.py``.
"""
import argparse
import glob
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCH_KEYS = ("efficiency", "selection_f1", "selection_real", "kernels",
              "serving", "scale", "overload", "deep")

# the bench-record schema BENCH_*.json files are validated against
RECORD_REQUIRED = {
    "bench": str,
    "name": str,
    "us_per_call": (int, float),
    "derived": str,
    "backend": str,
    "tuned_blocks": dict,
    "git_rev": str,
}
RECORD_OPTIONAL = {"value": (int, float), "metrics": dict}

# smoke gate: fail when serving/batch_speedup drops below this fraction
# of the committed baseline
REGRESSION_FLOOR = 0.8


def _ensure_paths():
    """Script mode (`python benchmarks/run.py`) has neither the repo root
    nor src/ importable; module mode already does."""
    for p in (ROOT, os.path.join(ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def _setup_runtime(verbose: bool = False):
    """Runtime env policy + tune-cache location, before jax is pulled in."""
    os.environ.setdefault("REPRO_TUNE_CACHE",
                          os.path.join(ROOT, "benchmarks",
                                       "tuned_blocks.json"))
    _ensure_paths()
    from repro.launch import runtime
    runtime.apply()
    if verbose:
        runtime.log()
    return runtime


def _import_benches():
    try:
        from . import (bench_deep, bench_efficiency, bench_kernels,
                       bench_overload, bench_scale, bench_selection_f1,
                       bench_selection_real, bench_serving)
    except ImportError:
        from benchmarks import (bench_deep, bench_efficiency, bench_kernels,
                                bench_overload, bench_scale,
                                bench_selection_f1, bench_selection_real,
                                bench_serving)
    return {
        "efficiency": bench_efficiency.run,       # paper Fig. 1 + App. D.1
        "selection_f1": bench_selection_f1.run,   # paper Fig. 2
        "selection_real": bench_selection_real.run,  # paper Figs. 3/4
        "kernels": bench_kernels.run,             # Cor. 3.3 machinery
        "serving": bench_serving.run,             # inference subsystem
        "scale": bench_scale.run,                 # streaming + sharded n
        "overload": bench_overload.run,           # robustness under overload
        "deep": bench_deep.run,                   # FastCPH-style deep head
    }


# -- structured records -----------------------------------------------------

def _git_rev() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT, text=True,
            stderr=subprocess.DEVNULL).strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _run_metadata():
    """(backend, tuned_blocks-for-backend, git_rev) stamped on records."""
    import jax
    from repro.kernels import autotune
    backend = jax.default_backend()
    entries = autotune.load_cache(refresh=True)
    tuned = {k: dict(v.get("config", {})) for k, v in entries.items()
             if k.startswith(backend + "/")}
    return backend, tuned, _git_rev()


def make_records(bench, rows, backend, tuned, git_rev):
    recs = []
    for row in rows:
        rec = {"bench": bench, "name": row[0],
               "us_per_call": float(row[1]), "derived": str(row[2]),
               "backend": backend, "tuned_blocks": tuned,
               "git_rev": git_rev}
        if len(row) > 3 and row[3] is not None:
            rec["value"] = float(row[3])
        recs.append(rec)
    return recs


def validate_records(records):
    """Schema errors for a BENCH_*.json payload ([] when valid)."""
    if not isinstance(records, list) or not records:
        return ["payload must be a non-empty list of records"]
    errors = []
    for i, r in enumerate(records):
        if not isinstance(r, dict):
            errors.append(f"record {i}: not an object")
            continue
        label = r.get("name", f"record {i}")
        for k, t in RECORD_REQUIRED.items():
            if k not in r:
                errors.append(f"{label}: missing required key '{k}'")
            elif not isinstance(r[k], t):
                errors.append(f"{label}: key '{k}' has type "
                              f"{type(r[k]).__name__}")
        for k, t in RECORD_OPTIONAL.items():
            if k in r and not isinstance(r[k], t):
                errors.append(f"{label}: key '{k}' has type "
                              f"{type(r[k]).__name__}")
    return errors


def validate_metrics_snapshot(snap):
    """Schema errors for an obs Registry.snapshot() embedding ([] = valid).

    Shape: ``{"counters"|"gauges": {name: {label_str: number}},
    "histograms": {name: {"buckets": [num...], "series":
    {label_str: {"counts": [int...], "sum": num, "count": int}}}}``.
    """
    errors = []
    if not isinstance(snap, dict):
        return ["metrics snapshot must be an object"]
    for group in ("counters", "gauges", "histograms"):
        if group not in snap or not isinstance(snap[group], dict):
            errors.append(f"metrics: missing/invalid group '{group}'")
    for group in ("counters", "gauges"):
        series_by_name = snap.get(group)
        if not isinstance(series_by_name, dict):
            continue
        for name, series in series_by_name.items():
            if not isinstance(series, dict) or not all(
                    isinstance(v, (int, float)) for v in series.values()):
                errors.append(f"metrics: {group}/{name} series not "
                              "label->number")
    hists = snap.get("histograms")
    for name, h in (hists.items() if isinstance(hists, dict) else ()):
        if not isinstance(h, dict) or not isinstance(h.get("buckets"), list):
            errors.append(f"metrics: histograms/{name} missing buckets")
            continue
        series = h.get("series")
        for label, s in (series.items() if isinstance(series, dict) else ()):
            ok = (isinstance(s, dict) and isinstance(s.get("counts"), list)
                  and isinstance(s.get("sum"), (int, float))
                  and isinstance(s.get("count"), int)
                  and len(s["counts"]) == len(h["buckets"]) + 1)
            if not ok:
                errors.append(
                    f"metrics: histograms/{name}[{label!r}] malformed")
    return errors


def _solver_violations(snap) -> float:
    counters = snap.get("counters", {})
    series = counters.get("solver_monotonicity_violations_total", {})
    return sum(series.values()) if isinstance(series, dict) else 0.0


def _telemetry_record(backend, tuned, git_rev, n_iters=25):
    """Instrumented smoke fit + full registry snapshot as a bench record.

    Runs ``fit_cd_tol`` on a small synthetic problem with a
    ``TelemetryCallback``, so the embedded snapshot carries convergence
    iteration counts and the monotonicity-violation counter alongside
    whatever serving/kernel metrics the benches accumulated.
    """
    import jax

    from repro.core import cox, solvers
    from repro.data.synthetic import SyntheticSpec, make_correlated_survival
    from repro.obs import REGISTRY, TelemetryCallback

    x, t, delta, _ = make_correlated_survival(
        SyntheticSpec(n=200, p=20, k=4, rho=0.3, seed=0))
    data = cox.prepare(x, t, delta)
    tel = TelemetryCallback("cd_quad_smoke")
    res = solvers.fit_cd_tol(data, 0.1, 0.5, max_iters=n_iters,
                             telemetry=tel)
    res.beta.block_until_ready()
    jax.effects_barrier()          # flush the debug callbacks
    snap = REGISTRY.snapshot()
    return {
        "bench": "telemetry", "name": "metrics_snapshot",
        "us_per_call": 0.0,
        "derived": (f"smoke_fit_iters={tel.iterations} "
                    f"violations={tel.violations}"),
        "value": float(tel.violations),
        "backend": backend, "tuned_blocks": tuned, "git_rev": git_rev,
        "metrics": snap,
    }


def _baseline_record(bench, name):
    """Matching record from the newest committed BENCH_*.json, if any."""
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        return None, None
    path = paths[-1]
    try:
        with open(path) as f:
            records = json.load(f)
    except (OSError, ValueError):
        return None, path
    for r in records if isinstance(records, list) else []:
        if (isinstance(r, dict) and r.get("bench") == bench
                and r.get("name") == name):
            return r, path
    return None, path


def _print_rows(rows):
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)


# -- CI smoke gate ----------------------------------------------------------

def _smoke() -> int:
    """Tier-1 pytest on the serving path, tiny autotune sweep, tiny-shape
    serving bench with schema validation, speedup regression gate."""
    import tempfile

    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    tests = [os.path.join(ROOT, "tests", f)
             for f in ("test_serving.py", "test_robustness.py",
                       "test_kernels.py", "test_autotune.py",
                       "test_pspec.py")]
    print("[smoke] tier-1:", "python -m pytest -x -q", *tests, flush=True)
    rc = subprocess.call([sys.executable, "-m", "pytest", "-x", "-q",
                          *tests], env=env, cwd=ROOT)
    if rc != 0:
        print("[smoke] FAILED: tier-1 tests")
        return rc

    from repro.kernels import autotune
    with tempfile.TemporaryDirectory() as td:
        winners = autotune.sweep(
            [("revcumsum", {"n": 256, "m": 8}),
             ("survival_curves", {"b": 32, "g": 32})],
            cache_file=os.path.join(td, "tuned.json"), reps=1)
    if len(winners) != 2 or not all(winners.values()):
        print("[smoke] FAILED: autotune sweep returned no winners")
        return 1
    print(f"[smoke] autotune sweep ok: "
          + "; ".join(f"{k} -> {v}" for k, v in winners.items()),
          flush=True)

    benches = _import_benches()
    print("name,us_per_call,derived")
    rows = list(benches["serving"](smoke=True))
    _print_rows(rows)
    speedup = next((row[3] for row in rows
                    if row[0] == "serving/batch_speedup" and len(row) > 3),
                   None)
    if speedup is None or speedup <= 1.0:
        print("[smoke] FAILED: batched serving slower than naive loop "
              f"(speedup={speedup})")
        return 1

    backend, tuned, rev = _run_metadata()
    records = make_records("serving_smoke", rows, backend, tuned, rev)
    errors = validate_records(records)
    if errors:
        print("[smoke] FAILED: bench records violate schema:")
        for e in errors:
            print(f"[smoke]   {e}")
        return 1
    print(f"[smoke] schema ok ({len(records)} records)")

    base, path = _baseline_record("serving_smoke", "serving/batch_speedup")
    if base is not None and "value" in base:
        floor = REGRESSION_FLOOR * base["value"]
        if speedup < floor:
            print(f"[smoke] FAILED: serving/batch_speedup x{speedup:.2f} "
                  f"regressed >20% vs baseline x{base['value']:.2f} "
                  f"({os.path.basename(path)})")
            return 1
        print(f"[smoke] speedup x{speedup:.2f} within 20% of baseline "
              f"x{base['value']:.2f} ({os.path.basename(path)})")
    else:
        print("[smoke] no committed BENCH_*.json baseline — "
              "regression gate skipped")

    # telemetry gate: an instrumented smoke fit must record zero
    # monotonicity violations, and its snapshot must satisfy the schema
    tel_rec = _telemetry_record(backend, tuned, rev)
    errors = (validate_records([tel_rec])
              + validate_metrics_snapshot(tel_rec["metrics"]))
    if errors:
        print("[smoke] FAILED: telemetry snapshot violates schema:")
        for e in errors:
            print(f"[smoke]   {e}")
        return 1
    violations = _solver_violations(tel_rec["metrics"])
    if violations > 0:
        print(f"[smoke] FAILED: {int(violations)} monotonicity "
              "violation(s) recorded during the smoke fit — the "
              "surrogate descent guarantee is broken")
        return 1
    print(f"[smoke] telemetry ok ({tel_rec['derived']})")

    # streaming-fit gate: a tiny fit_stream must descend monotonically
    # (zero violations on the live counter) through the same telemetry
    try:
        from . import bench_scale
    except ImportError:
        from benchmarks import bench_scale
    from repro.core import solvers
    from repro.obs import TelemetryCallback
    tel = TelemetryCallback("fit_stream_smoke")
    src = bench_scale.SyntheticChunkSource(1500, 8, 512, seed=0)
    res = solvers.fit_stream(src, lam2=0.05, n_epochs=3, telemetry=tel)
    if tel.violations > 0 or tel.iterations < 1:
        print(f"[smoke] FAILED: streaming fit recorded "
              f"{tel.violations} violation(s) over {tel.iterations} "
              "epoch(s)")
        return 1
    print(f"[smoke] streaming fit ok (epochs={tel.iterations} "
          f"violations={tel.violations} "
          f"objective={float(res.objective[-1]):.2f})")

    # 2-shard host-mesh scoring check: the subprocess asserts sharded ==
    # unsharded bit-for-bit before reporting timings
    try:
        rows = bench_scale._scoring_rows(buckets=(2048,), reps=2)
    except RuntimeError as e:
        print(f"[smoke] FAILED: sharded scoring check: {e}")
        return 1
    _print_rows(rows)
    print("[smoke] 2-shard scoring parity ok")

    # BENCH_8 gate: when the scale artifact is committed it must satisfy
    # the record schema and carry the shard-speedup headline
    b8 = os.path.join(ROOT, "BENCH_8.json")
    if os.path.exists(b8):
        try:
            with open(b8) as f:
                b8_records = json.load(f)
        except (OSError, ValueError) as e:
            print(f"[smoke] FAILED: BENCH_8.json unreadable: {e}")
            return 1
        errors = validate_records(b8_records)
        if errors:
            print("[smoke] FAILED: BENCH_8.json violates schema:")
            for e in errors:
                print(f"[smoke]   {e}")
            return 1
        speedups = [r.get("value") for r in b8_records
                    if isinstance(r, dict) and "shard_speedup"
                    in str(r.get("name", ""))]
        if not speedups:
            print("[smoke] FAILED: BENCH_8.json has no shard_speedup record")
            return 1
        print(f"[smoke] BENCH_8.json ok ({len(b8_records)} records, "
              f"shard speedup x{max(speedups):.2f})")
    else:
        print("[smoke] no BENCH_8.json committed yet — scale gate skipped")

    # overload gate: a tiny open-loop run must keep HIGH-priority p99
    # bounded past saturation, drop nothing during a live hot swap, and
    # account for every submitted request (zero silent loss)
    rows = list(benches["overload"](smoke=True))
    _print_rows(rows)
    vals = {row[0]: row[3] for row in rows if len(row) > 3}
    p99_2x = vals.get("overload/p99_high@2x")     # milliseconds
    if p99_2x is None or not 0.0 < p99_2x <= 500.0:
        print("[smoke] FAILED: overload p99_high@2x unbounded or missing "
              f"({None if p99_2x is None else f'{p99_2x:.1f}ms'})")
        return 1
    if vals.get("overload/silent_loss", 1.0) != 0.0:
        print("[smoke] FAILED: overload run lost requests silently "
              f"({vals.get('overload/silent_loss')})")
        return 1
    if vals.get("overload/hot_swap_dropped", 1.0) != 0.0:
        print("[smoke] FAILED: hot swap under load dropped requests "
              f"({vals.get('overload/hot_swap_dropped')})")
        return 1
    print(f"[smoke] overload ok (p99_high@2x={p99_2x:.1f}ms, "
          "hot swap zero-drop)")

    # BENCH_9 gate: the committed overload artifact must satisfy the
    # record schema and carry a zero-drop hot swap + zero silent loss
    b9 = os.path.join(ROOT, "BENCH_9.json")
    if os.path.exists(b9):
        try:
            with open(b9) as f:
                b9_records = json.load(f)
        except (OSError, ValueError) as e:
            print(f"[smoke] FAILED: BENCH_9.json unreadable: {e}")
            return 1
        errors = validate_records(b9_records)
        if errors:
            print("[smoke] FAILED: BENCH_9.json violates schema:")
            for e in errors:
                print(f"[smoke]   {e}")
            return 1
        by_name = {r.get("name"): r.get("value")
                   for r in b9_records if isinstance(r, dict)}
        for key in ("overload/p99_high@2x", "overload/hot_swap_dropped",
                    "overload/silent_loss"):
            if key not in by_name:
                print(f"[smoke] FAILED: BENCH_9.json missing '{key}'")
                return 1
        if by_name["overload/hot_swap_dropped"] != 0.0:
            print("[smoke] FAILED: committed BENCH_9.json records a "
                  "lossy hot swap")
            return 1
        if by_name["overload/silent_loss"] != 0.0:
            print("[smoke] FAILED: committed BENCH_9.json records "
                  "silent request loss")
            return 1
        print(f"[smoke] BENCH_9.json ok ({len(b9_records)} records, "
              f"p99_high@2x={by_name['overload/p99_high@2x']:.1f}ms)")
    else:
        print("[smoke] no BENCH_9.json committed yet — overload gate on "
              "committed artifact skipped")

    # deep-survival gate: a tiny train -> refit -> export run must learn a
    # better-than-random deep head and the exported artifact must serve
    # through ModelRegistry/RiskService with exactly the sparse head's
    # risks (the zoo + solver + serving stack all meeting in one path —
    # the 41-test get_abstract_mesh break would fail here immediately)
    rows = list(benches["deep"](smoke=True))
    _print_rows(rows)
    vals = {row[0]: row[3] for row in rows if len(row) > 3}
    ci_deep = vals.get("deep/cindex_deep")
    if ci_deep is None or not 0.55 <= ci_deep <= 1.0:
        print("[smoke] FAILED: deep head c-index missing or ~random "
              f"({ci_deep})")
        return 1
    if vals.get("deep/served_match", 0.0) != 1.0:
        print("[smoke] FAILED: served risks diverge from the sparse "
              f"refit head (match={vals.get('deep/served_match')})")
        return 1
    print(f"[smoke] deep survival ok (cindex_deep={ci_deep:.3f}, "
          "served risks match)")

    # BENCH_10 gate: the committed deep artifact must satisfy the record
    # schema, carry the c-index headline, and record a clean serving match
    b10 = os.path.join(ROOT, "BENCH_10.json")
    if os.path.exists(b10):
        try:
            with open(b10) as f:
                b10_records = json.load(f)
        except (OSError, ValueError) as e:
            print(f"[smoke] FAILED: BENCH_10.json unreadable: {e}")
            return 1
        errors = validate_records(b10_records)
        if errors:
            print("[smoke] FAILED: BENCH_10.json violates schema:")
            for e in errors:
                print(f"[smoke]   {e}")
            return 1
        by_name = {r.get("name"): r.get("value")
                   for r in b10_records if isinstance(r, dict)}
        for key in ("deep/train", "deep/refit", "deep/cindex_deep",
                    "deep/cindex_linear", "deep/served_match"):
            if key not in by_name:
                print(f"[smoke] FAILED: BENCH_10.json missing '{key}'")
                return 1
        if by_name["deep/served_match"] != 1.0:
            print("[smoke] FAILED: committed BENCH_10.json records a "
                  "serving mismatch")
            return 1
        print(f"[smoke] BENCH_10.json ok ({len(b10_records)} records, "
              f"cindex_deep={by_name['deep/cindex_deep']:.3f} vs "
              f"linear={by_name['deep/cindex_linear']:.3f})")
    else:
        print("[smoke] no BENCH_10.json committed yet — deep gate on "
              "committed artifact skipped")
    print("[smoke] OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma-separated subset of "
                         f"{','.join(BENCH_KEYS)} (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI guard: serving tests + tiny benches + "
                         "autotune sweep + schema/regression gates")
    ap.add_argument("--json", metavar="PATH",
                    help="write structured bench records (BENCH_*.json)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the default block-size sweep first; winners "
                         "persist to $REPRO_TUNE_CACHE and are used by "
                         "the benches")
    args = ap.parse_args()

    _setup_runtime(verbose=not args.smoke)
    if args.smoke:
        sys.exit(_smoke())

    selected = (set(BENCH_KEYS) if args.only == "all"
                else {s.strip() for s in args.only.split(",") if s.strip()})
    unknown = selected - set(BENCH_KEYS)
    if unknown:
        ap.error(f"unknown bench(es): {','.join(sorted(unknown))}")

    if args.autotune:
        from repro.kernels import autotune
        autotune.sweep(verbose=True)

    benches = _import_benches()
    backend, tuned, rev = _run_metadata()
    records = []
    print("name,us_per_call,derived")
    for key, fn in benches.items():
        if key not in selected:
            continue
        rows = list(fn())
        _print_rows(rows)
        records += make_records(key, rows, backend, tuned, rev)

    if args.json:
        if "serving" in selected:
            # a tiny-shape serving pass rides along so --smoke has an
            # apples-to-apples baseline for its regression gate
            rows = list(benches["serving"](smoke=True))
            records += make_records("serving_smoke", rows, backend, tuned,
                                    rev)
        # embed the metrics snapshot (serving/kernel counters accumulated
        # by the benches + an instrumented convergence smoke fit)
        tel_rec = _telemetry_record(backend, tuned, rev)
        merrors = validate_metrics_snapshot(tel_rec["metrics"])
        if merrors:
            for e in merrors:
                print(f"[json] schema error: {e}", file=sys.stderr)
            sys.exit(1)
        records.append(tel_rec)
        errors = validate_records(records)
        if errors:
            for e in errors:
                print(f"[json] schema error: {e}", file=sys.stderr)
            sys.exit(1)
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[json] wrote {len(records)} records -> {args.json}")


if __name__ == "__main__":
    main()
