"""Paper Figs. 3/4 (+App D.2): support size vs CIndex and IBS on a
binarized, highly-correlated dataset (attrition-like generator standing in
for the Employee-Attrition preprocessing — no external data offline).
Train/test split; beam-search CD (ours) vs the l1 path."""
import numpy as np

from repro.core import beam, cox, path
from repro.data.synthetic import make_attrition_like
from repro.survival import metrics


def run(n=1200, k_max=10):
    x, t, delta = make_attrition_like(n=n, n_cont=5, thresholds=30, seed=0)
    ntr = int(0.8 * n)
    data_tr = cox.prepare(x[:ntr], t[:ntr], delta[:ntr])
    rows = []
    res_b = beam.beam_search(data_tr, k=k_max, beam_width=4, n_expand=6)
    res_l1 = path.l1_path(data_tr, n_lambdas=16, lambda_min_ratio=0.01,
                          n_iters=60)
    for label, betas, sizes in (
        ("beam", res_b.betas, [len(s) for s in res_b.supports]),
        ("l1path", list(res_l1.betas),
         list(res_l1.support_sizes)),
    ):
        best = {}
        for b, s in zip(betas, sizes):
            if s == 0 or s > k_max:
                continue
            eta_tr = x[:ntr] @ b
            eta_te = x[ntr:] @ b
            ci = metrics.cindex(t[ntr:], delta[ntr:], eta_te)
            ib = metrics.ibs(t[:ntr], delta[:ntr], eta_tr,
                             t[ntr:], delta[ntr:], eta_te)
            if s not in best or ci > best[s][0]:
                best[s] = (ci, ib)
        for s in sorted(best):
            ci, ib = best[s]
            rows.append((f"selection_real/{label}/k={s}", 0.0,
                         f"cindex={ci:.3f};ibs={ib:.3f}"))
    return rows
