"""Paper Fig. 2: variable selection under high correlation (rho = 0.9),
F1 vs support size, three sample sizes; beam-search CD (ours) vs greedy
OMP and the l1 path (coxnet analogue). Sizes reduced for the 1-core CPU
container; the regime (p = n, rho = 0.9, k-sparse truth) matches the paper.
"""
import time

import numpy as np

from repro.core import beam, cox, path
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.survival import metrics


def run(sizes=(600, 400, 300), p=300, k=10):
    # censor_scale=3.0 reproduces the paper's effective event rate (~70%;
    # its literal Eq. 30 indicator yields mostly-observed events — see
    # data/synthetic.py docstring for the discrepancy note)
    rows = []
    for n in sizes:
        x, t, delta, beta_star = make_correlated_survival(
            SyntheticSpec(n=n, p=p, k=k, rho=0.9, seed=1, censor_scale=3.0))
        data = cox.prepare(x, t, delta)
        t0 = time.perf_counter()
        res_b = beam.beam_search(data, k=k, beam_width=4, n_expand=6)
        dt_b = time.perf_counter() - t0
        res_o = beam.omp_greedy(data, k=k)
        res_l1 = path.l1_path(data, n_lambdas=16, lambda_min_ratio=0.02,
                              n_iters=60)
        f1_b = metrics.support_f1(beta_star, res_b.betas[-1])[2]
        f1_o = metrics.support_f1(beta_star, res_o.betas[-1])[2]
        f1_l = 0.0
        for b, s in zip(res_l1.betas, res_l1.support_sizes):
            if s <= k:
                f1_l = max(f1_l, metrics.support_f1(beta_star, b)[2])
        rows.append((f"selection_f1/beam/n={n}", dt_b / k * 1e6,
                     f"f1={f1_b:.3f}"))
        rows.append((f"selection_f1/omp/n={n}", 0.0, f"f1={f1_o:.3f}"))
        rows.append((f"selection_f1/l1path/n={n}", 0.0, f"f1={f1_l:.3f}"))
    return rows
