import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

Three cells (harness criteria):
  A. deepseek-67b train_4k   — worst memory blow-up (temp 285 GB/dev)
  B. mamba2-130m train_4k    — most collective-bound baseline
  C. distributed CPH CD      — the paper's own technique at production scale

Each variant is lowered+compiled on the production mesh; we record
memory_analysis, extrapolated flops/bytes/collectives (same probe scheme as
dryrun), and append to benchmarks/results/perf_log.json.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.analysis import roofline as rl                    # noqa: E402
from repro.configs import SHAPES, TrainConfig, get_config    # noqa: E402
from repro.launch import dryrun                              # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "results")


def measure(lowered, n_dev=256):
    cm = lowered.compile()
    try:
        ma = cm.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes") if hasattr(ma, k)}
    except Exception as e:
        mem = {"error": str(e)}
    ca = cm.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = rl.parse_collectives(cm.as_text())
    return {"memory": mem,
            "flops_raw": float(ca.get("flops", 0.0)),
            "bytes_raw": float(ca.get("bytes accessed", 0.0)),
            "coll_raw": coll.to_json()}


def probe_terms(arch, shape_name, **knobs):
    """Depth-extrapolated (flops, bytes, coll_moved) per device."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    probes = {}
    for u in (1, 2):
        lw, *_ = dryrun.lower_cell(
            arch, shape_name, False,
            cfg_override=dryrun.analysis_config(cfg, shape, u), **knobs)
        cm = lw.compile()
        ca = cm.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        pc = rl.parse_collectives(cm.as_text())
        probes[u] = (float(ca.get("flops", 0)),
                     float(ca.get("bytes accessed", 0)), pc.moved_bytes)
    units = dryrun.depth_units_of(cfg)
    f, b, c = (probes[1][i] + (units - 1) * (probes[2][i] - probes[1][i])
               for i in range(3))
    return {"flops": f, "bytes": b, "coll": c,
            "compute_s": f / rl.PEAK_FLOPS, "memory_s": b / rl.HBM_BW,
            "collective_s": c / rl.ICI_BW}


def _terms_for(cfg, shape_name, tcfg=None):
    shape = SHAPES[shape_name]
    probes = {}
    for u in (1, 2):
        cfg_u = dryrun.analysis_config(cfg, shape, u)
        lw, *_ = dryrun.lower_cell(cfg.name, shape_name, False,
                                   cfg_override=cfg_u, tcfg=tcfg)
        cm = lw.compile()
        ca = cm.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        pc = rl.parse_collectives(cm.as_text())
        probes[u] = (float(ca.get("flops", 0)),
                     float(ca.get("bytes accessed", 0)), pc.moved_bytes)
    units = dryrun.depth_units_of(cfg)
    f, b, c = (probes[1][i] + (units - 1) * (probes[2][i] - probes[1][i])
               for i in range(3))
    return {"flops": f, "bytes": b, "coll": c,
            "compute_s": f / rl.PEAK_FLOPS, "memory_s": b / rl.HBM_BW,
            "collective_s": c / rl.ICI_BW}


def log(name, rec):
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "perf_log.json")
    hist = []
    if os.path.exists(path):
        hist = json.load(open(path))
    hist.append({"name": name, **rec, "t": time.strftime("%H:%M:%S")})
    json.dump(hist, open(path, "w"), indent=1)
    print(f"[perf] {name}: {json.dumps(rec)[:240]}", flush=True)


# ---------------------------------------------------------------------------
# Experiment A / B: train-cell variants
# ---------------------------------------------------------------------------

def train_variant(arch, name, *, microbatch=0, param_mode=None,
                  donate=False, with_probes=False):
    tcfg = TrainConfig(microbatch=microbatch) if microbatch else None
    lw, *_ = dryrun.lower_cell(arch, "train_4k", False, tcfg=tcfg,
                               param_mode=param_mode, donate=donate)
    rec = measure(lw)
    if with_probes:
        rec["terms"] = probe_terms(arch, "train_4k",
                                   param_mode=param_mode, donate=donate)
    log(f"{arch}/train_4k/{name}", rec)
    return rec


# ---------------------------------------------------------------------------
# Experiment C: distributed CPH (the paper's technique)
# ---------------------------------------------------------------------------

def cph_variants(n=1 << 22, p=2048):
    from repro.core import cox, distributed, surrogate
    mesh = make_production_mesh()
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding

    x = jax.ShapeDtypeStruct((n, p), jnp.float32,
                             sharding=NS(mesh, P("data", "model")))
    vec = jax.ShapeDtypeStruct((n,), jnp.float32,
                               sharding=NS(mesh, P("data")))
    pvec = jax.ShapeDtypeStruct((p,), jnp.float32,
                                sharding=NS(mesh, P("model")))
    rs = jax.ShapeDtypeStruct((n,), jnp.int32, sharding=NS(mesh, P("data")))
    data = cox.CoxData(x=x, delta=vec, risk_start=rs, tie_end=rs)

    with jax.set_mesh(mesh):
        # C0: GSPMD-auto partitioning of one CD coordinate touch
        def cd_coord_auto(data, eta, beta, l2c):
            xl = data.x[:, 0]
            g, _, _ = cox.coord_derivs(data, eta, xl, order=2)
            step = surrogate.quad_l1_prox(g, l2c[0], beta[0], 0.0)
            return eta + step * xl, beta.at[0].add(step)

        lw = jax.jit(cd_coord_auto).lower(data, vec, pvec, pvec)
        log("cph/C0_gspmd_auto_per_coord", measure(lw))

        # C1: shard_map decoupled-scan CD coordinate touch
        def cd_coord_shardmap(data, eta, beta, l2c):
            xl = data.x[:, 0]
            w, s0, a = distributed.sharded_risk_stats(data, eta, mesh)
            g = jnp.sum((w * a - data.delta) * xl)
            step = surrogate.quad_l1_prox(g, l2c[0], beta[0], 0.0)
            return eta + step * xl, beta.at[0].add(step)

        lw = jax.jit(cd_coord_shardmap).lower(data, vec, pvec, pvec)
        log("cph/C1_shardmap_scan_per_coord", measure(lw))

        # C2: beyond-paper GEMV full-gradient pass (all p coordinates)
        def full_grad(data, eta):
            return distributed.sharded_grad_hess_all(data, eta, mesh)

        lw = jax.jit(full_grad).lower(data, vec)
        log("cph/C2_gemv_all_p", measure(lw))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    choices=["all", "A", "B", "C", "A4", "B2", "B3", "B4", "B5",
                             "B6", "A5", "A6"])
    args = ap.parse_args()
    if args.exp in ("all", "A"):
        train_variant("deepseek-67b", "A1_donate", donate=True)
        train_variant("deepseek-67b", "A2_microbatch8",
                      microbatch=8, donate=True)
        train_variant("deepseek-67b", "A3_microbatch16",
                      microbatch=16, donate=True)
    if args.exp == "A4":
        train_variant("deepseek-67b", "A4_microbatch32",
                      microbatch=32, donate=True)
    if args.exp == "B2":
        mamba2_pure_dp()
    if args.exp == "B3":
        mamba2_hybrid_dp()
    if args.exp == "B4":
        cfg = get_config("mamba2-130m").scaled(ssm_chunk=64)
        lw, *_ = dryrun.lower_cell("mamba2-130m", "train_4k", False,
                                   cfg_override=cfg, donate=True)
        rec = measure(lw)
        rec["terms"] = _terms_for(cfg, "train_4k")
        log("mamba2-130m/train_4k/B4_ssd_chunk64", rec)
    if args.exp == "B6":
        tc = TrainConfig(remat="dots")
        lw, *_ = dryrun.lower_cell("mamba2-130m", "train_4k", False,
                                   tcfg=tc, donate=True)
        rec = measure(lw)
        rec["terms"] = _terms_for(get_config("mamba2-130m"), "train_4k",
                                  tcfg=tc)
        log("mamba2-130m/train_4k/B6_remat_dots", rec)
    if args.exp == "A6":
        deepseek_flat_fsdp()
    if args.exp == "A5":
        tc = TrainConfig(microbatch=16, remat="dots")
        lw, *_ = dryrun.lower_cell("deepseek-67b", "train_4k", False,
                                   tcfg=tc, donate=True)
        log("deepseek-67b/train_4k/A5_mb16_remat_dots", measure(lw))
    if args.exp == "B5":
        tc = TrainConfig(remat=False)
        lw, *_ = dryrun.lower_cell("mamba2-130m", "train_4k", False,
                                   tcfg=tc, donate=True)
        rec = measure(lw)
        rec["terms"] = _terms_for(get_config("mamba2-130m"), "train_4k",
                                  tcfg=tc)
        log("mamba2-130m/train_4k/B5_no_remat", rec)
    if args.exp in ("all", "B"):
        train_variant("mamba2-130m", "B1_no_fsdp", param_mode="serve",
                      donate=True, with_probes=True)
        train_variant("mamba2-130m", "B0_baseline_probes", donate=False,
                      with_probes=True)
    if args.exp in ("all", "C"):
        cph_variants()


# ---------------------------------------------------------------------------
# Round-2 variants (added after round-1 measurements; see EXPERIMENTS.md)
# ---------------------------------------------------------------------------

def mamba2_pure_dp():
    """B2: for a 130M model, TP is pure overhead — use the model axis as
    extra data parallelism (batch 256 over all 256 chips, params
    replicated). Hypothesis: collective term collapses to the single grad
    all-reduce (~2 * 0.7GB * 255/256 / 50GB/s ~ 28ms) from 1.43s."""
    from repro.models import build_model
    from repro.train.trainer import TrainState, make_train_step
    from repro.train import optimizer as opt_lib
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config("mamba2-130m")
    shape = SHAPES["train_4k"]
    model = build_model(cfg)
    mesh = make_production_mesh()
    with jax.set_mesh(mesh):
        pshape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        repl = NamedSharding(mesh, P())
        params = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=repl),
            pshape)
        opt_shape = jax.eval_shape(opt_lib.init_opt_state, params)
        opt = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=repl),
            opt_shape)
        state = TrainState(params=params, opt=opt)
        bsh = NamedSharding(mesh, P(("data", "model"), None))
        batch = {k: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=bsh)
                 for k, l in model.make_input_specs(shape).items()}
        step_fn = make_train_step(model, TrainConfig())
        lw = jax.jit(step_fn, donate_argnums=(0,)).lower(state, batch)
        rec = measure(lw)
        # probes: depth-extrapolated terms under the same layout
        probes = {}
        for u in (1, 2):
            cfg_u = dryrun.analysis_config(cfg, shape, u)
            model_u = build_model(cfg_u)
            pshape_u = jax.eval_shape(model_u.init_params,
                                      jax.random.PRNGKey(0))
            params_u = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                               sharding=repl), pshape_u)
            opt_u = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                               sharding=repl),
                jax.eval_shape(opt_lib.init_opt_state, params_u))
            st_u = TrainState(params=params_u, opt=opt_u)
            fn_u = make_train_step(model_u, TrainConfig())
            cm = jax.jit(fn_u).lower(st_u, batch).compile()
            ca = cm.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            pc = rl.parse_collectives(cm.as_text())
            probes[u] = (float(ca.get("flops", 0)),
                         float(ca.get("bytes accessed", 0)), pc.moved_bytes)
        units = dryrun.depth_units_of(cfg)
        f, b, c = (probes[1][i] + (units - 1)
                   * (probes[2][i] - probes[1][i]) for i in range(3))
        rec["terms"] = {"flops": f, "bytes": b, "coll": c,
                        "compute_s": f / rl.PEAK_FLOPS,
                        "memory_s": b / rl.HBM_BW,
                        "collective_s": c / rl.ICI_BW}
        log("mamba2-130m/train_4k/B2_pure_dp", rec)



def mamba2_hybrid_dp(name="B3_dp_blocks_sharded_head"):
    """B3: B2 showed pure DP kills the collective term (1.43s -> 0.018s)
    but the replicated vocab head inflates the memory term (1.54 -> 4.29s).
    Hypothesis: keep ONLY embed/lm_head model-sharded (vocab 50432 -> 3152
    per chip) and replicate the tiny mamba blocks; batch over data only so
    the logits CE stays sharded in both vocab and batch. Expect memory_s
    back near baseline with collective_s staying ~two orders below it."""
    from repro.models import build_model
    from repro.train.trainer import TrainState, make_train_step
    from repro.train import optimizer as opt_lib
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config("mamba2-130m")
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh()

    def pspec_for(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        if names and names[-1] == "embed":
            return NamedSharding(mesh, P("model", None))
        if names and names[-1] == "lm_head":
            return NamedSharding(mesh, P(None, "model"))
        return NamedSharding(mesh, P())

    def lower_for(cfg_x):
        model = build_model(cfg_x)
        pshape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        params = jax.tree_util.tree_map_with_path(
            lambda p, l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                              sharding=pspec_for(p, l)),
            pshape)
        opt_shape = jax.eval_shape(opt_lib.init_opt_state, params)
        opt = jax.tree_util.tree_map_with_path(
            lambda p, l: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=pspec_for(p[1:], l)),
            opt_shape)
        state = TrainState(params=params, opt=opt)
        bsh = NamedSharding(mesh, P("data", None))
        batch = {k: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=bsh)
                 for k, l in build_model(cfg_x).make_input_specs(
                     shape).items()}
        return jax.jit(make_train_step(build_model(cfg_x), TrainConfig()),
                       donate_argnums=(0,)).lower(state, batch)

    with jax.set_mesh(mesh):
        rec = measure(lower_for(cfg))
        probes = {}
        for u in (1, 2):
            cm = lower_for(dryrun.analysis_config(cfg, shape, u)).compile()
            ca = cm.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            pc = rl.parse_collectives(cm.as_text())
            probes[u] = (float(ca.get("flops", 0)),
                         float(ca.get("bytes accessed", 0)), pc.moved_bytes)
        units = dryrun.depth_units_of(cfg)
        f, b, c = (probes[1][i] + (units - 1)
                   * (probes[2][i] - probes[1][i]) for i in range(3))
        rec["terms"] = {"flops": f, "bytes": b, "coll": c,
                        "compute_s": f / rl.PEAK_FLOPS,
                        "memory_s": b / rl.HBM_BW,
                        "collective_s": c / rl.ICI_BW}
        log(f"mamba2-130m/train_4k/{name}", rec)



def deepseek_flat_fsdp(name="A6_flat_fsdp_no_tp"):
    """A6: baseline TP(16)+FSDP(16) pays per-layer param all-gathers AND
    per-layer activation all-reduces. Napkin math: pure 256-way FSDP
    (params dim0 over data x model jointly, no TP) keeps the param
    all-gather (~1.4GB/layer x 3 passes) but deletes the TP activation
    all-reduces; predicted collective ~= 95*3*1.38GB*(255/256)/50GB/s
    ~ 7.9s vs 78.7s baseline. Memory: params 0.5GB/dev + full-vocab logits
    (chunked CE would bound it; measured below)."""
    from repro.models import build_model
    from repro.train.trainer import TrainState, make_train_step
    from repro.train import optimizer as opt_lib
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config("deepseek-67b")
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh()

    def pspec_for(path, leaf):
        # flat FSDP: shard the largest dim over BOTH axes when divisible
        for dim in range(len(leaf.shape) - 2, len(leaf.shape)):
            if dim >= 0 and leaf.shape[dim] % 256 == 0 \
                    and leaf.shape[dim] >= 4096:
                spec = [None] * len(leaf.shape)
                spec[dim] = ("data", "model")
                return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    def lower_for(cfg_x, tcfg=None):
        model = build_model(cfg_x)
        pshape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        params = jax.tree_util.tree_map_with_path(
            lambda p, l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                              sharding=pspec_for(p, l)),
            pshape)
        opt_shape = jax.eval_shape(opt_lib.init_opt_state, params)
        opt = jax.tree_util.tree_map_with_path(
            lambda p, l: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=pspec_for(p[1:], l)),
            opt_shape)
        state = TrainState(params=params, opt=opt)
        # 256-way pure DP: batch over BOTH axes so every chip computes
        bsh = NamedSharding(mesh, P(("data", "model"), None))
        batch = {k: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=bsh)
                 for k, l in model.make_input_specs(shape).items()}
        return jax.jit(make_train_step(model, tcfg or TrainConfig(
            microbatch=16)), donate_argnums=(0,)).lower(state, batch)

    from repro.models import pspec
    pspec.DP_INCLUDE_MODEL = True
    with jax.set_mesh(mesh):
        rec = measure(lower_for(cfg))
        probes = {}
        for u in (1, 2):
            cm = lower_for(dryrun.analysis_config(cfg, shape, u),
                           tcfg=TrainConfig()).compile()
            ca = cm.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            pc = rl.parse_collectives(cm.as_text())
            probes[u] = (float(ca.get("flops", 0)),
                         float(ca.get("bytes accessed", 0)), pc.moved_bytes)
        units = dryrun.depth_units_of(cfg)
        f, b, c = (probes[1][i] + (units - 1)
                   * (probes[2][i] - probes[1][i]) for i in range(3))
        rec["terms"] = {"flops": f, "bytes": b, "coll": c,
                        "compute_s": f / rl.PEAK_FLOPS,
                        "memory_s": b / rl.HBM_BW,
                        "collective_s": c / rl.ICI_BW}
        log(f"deepseek-67b/train_4k/{name}", rec)
    pspec.DP_INCLUDE_MODEL = False


if __name__ == "__main__":
    main()
