"""Scale benchmark: streaming fits and sharded scoring vs n.

Two sections, both emitted through run.py's schema-validated record path:

* ``scale/fit_full|fit_stream/n=…`` — training throughput (rows/sec, one
  outer iteration's worth of work) and peak *host* memory (tracemalloc,
  MB) for the monolithic ``fit_cd`` vs the chunked ``fit_stream``. The
  streaming rows generate chunks on the fly from a seeded factory — the
  full (n, p) matrix never exists host-side, so peak memory stays bounded
  by the chunk size while full-batch peaks at the materialized matrix.
  The largest n runs stream-only (the point of the streaming path).
* ``scale/scoring/shard=…`` — 1-vs-2-shard ``ScoringEngine.score``
  rows/sec at serving bucket sizes, run in a subprocess with two forced
  host devices (the harness keeps the parent at 1).
  ``scale/scoring/shard_speedup/...`` carries the headline ratio
  (acceptance: >= 1.5x at the largest bucket).

Rows are (name, us_per_call, derived[, value]) as in bench_serving.py.
"""
import json
import os
import subprocess
import sys
import time
import tracemalloc

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FIT_P = 32
STREAM_CHUNK = 32768
SCORING_BUCKETS = (16384, 65536)
SCORING_GRID = 128


class SyntheticChunkSource:
    """Chunk factory: tie-free, globally time-ordered synthetic survival
    chunks generated on demand (seeded per chunk, so random access and
    repeated passes see identical data). Never materializes (n, p)."""

    def __init__(self, n: int, p: int, chunk_rows: int, seed: int = 0):
        self.n, self.p = int(n), int(p)
        self.chunk_rows = int(chunk_rows)
        self.seed = int(seed)
        rng = np.random.default_rng(seed)
        k = max(p // 8, 1)
        self._beta_star = np.zeros(p, np.float32)
        self._beta_star[rng.choice(p, k, replace=False)] = \
            rng.choice([-1.0, 1.0], k).astype(np.float32)

    def __len__(self) -> int:
        return -(-self.n // self.chunk_rows)

    def __getitem__(self, i: int):
        from repro.core import streaming

        if not 0 <= i < len(self):
            raise IndexError(i)
        lo = i * self.chunk_rows
        m = min(self.chunk_rows, self.n - lo)
        rng = np.random.default_rng((self.seed + 1, i))
        x = (rng.standard_normal((m, self.p)) * 0.5).astype(np.float32)
        # rows are implicitly ordered by global index == ascending time
        # (tie-free); event probability tied to the true linear predictor
        eta = x @ self._beta_star
        pr = 1.0 / (1.0 + np.exp(-eta))
        delta = (rng.uniform(size=m) < 0.3 + 0.4 * pr).astype(np.float32)
        return streaming.Chunk(x=x, delta=delta)


def _materialized(source: SyntheticChunkSource):
    """Concatenate a chunk source into a monolithic CoxData (full-batch
    baseline only — this is exactly the allocation streaming avoids)."""
    import jax.numpy as jnp

    from repro.core import cox

    xs, ds = [], []
    for i in range(len(source)):
        c = source[i]
        xs.append(np.asarray(c.x))
        ds.append(np.asarray(c.delta))
    x = np.concatenate(xs)
    d = np.concatenate(ds)
    idx = jnp.arange(x.shape[0], dtype=jnp.int32)
    return cox.CoxData(x=jnp.asarray(x), delta=jnp.asarray(d),
                       risk_start=idx, tie_end=idx)


def _fit_rows(n_list, stream_only, iters, lam2=0.01):
    import jax

    from repro.core import solvers

    rows = []
    for n in n_list:
        src = SyntheticChunkSource(n, FIT_P, STREAM_CHUNK, seed=n)
        chunk_mb = STREAM_CHUNK * FIT_P * 4 / 1e6

        if n not in stream_only:
            tracemalloc.start()
            t0 = time.perf_counter()
            data = _materialized(src)
            res = solvers.fit_cd(data, lam2=lam2, n_iters=iters)
            jax.block_until_ready(res.beta)
            dt = time.perf_counter() - t0
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            rps = n * iters / dt
            rows.append((f"scale/fit_full/n={n}", dt * 1e6,
                         f"rows_per_s={rps:.0f} peak_mb={peak / 1e6:.1f} "
                         f"matrix_mb={n * FIT_P * 4 / 1e6:.1f}", rps))
            del data, res

        tracemalloc.start()
        t0 = time.perf_counter()
        res = solvers.fit_stream(src, lam2=lam2, n_epochs=iters)
        jax.block_until_ready(res.beta)
        dt = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        rps = n * iters / dt
        rows.append((f"scale/fit_stream/n={n}", dt * 1e6,
                     f"rows_per_s={rps:.0f} peak_mb={peak / 1e6:.1f} "
                     f"chunk_mb={chunk_mb:.1f} chunks={len(src)}", rps))
    return rows


# -- sharded scoring (subprocess: parent process keeps 1 device) ------------

_SCORING_SCRIPT = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.serving import ScoringEngine, fit_survival_model

buckets = json.loads(sys.argv[1])
grid = int(sys.argv[2])
reps = int(sys.argv[3])
p = 32

x, t, delta, beta_star = make_correlated_survival(
    SyntheticSpec(n=2000, p=p, k=4, rho=0.5, seed=0, censor_scale=3.0))
model = fit_survival_model(x, t, delta, beta_star, grid_size=grid)
rng = np.random.default_rng(1)
out = {}
ROUNDS = 3
for b in buckets:
    feats = rng.standard_normal((b, p)).astype(np.float32)
    # use_kernel=False: the jnp path is the production path on CPU
    # (Pallas only interprets here)
    engines = {s: ScoringEngine(model, use_sparse=False, use_kernel=False,
                                shard=None if s == 1 else s)
               for s in (1, 2)}
    for eng in engines.values():
        eng.score(feats); eng.score(feats)     # warm the bucket jit
    # sustained mean over `reps` calls is the serving throughput metric;
    # alternating rounds + min-of-round-means damp host noise on a
    # shared box (both arms sample the same interference)
    best = {1: float("inf"), 2: float("inf")}
    for _ in range(ROUNDS):
        for shard, eng in engines.items():
            t0 = time.perf_counter()
            for _ in range(reps):
                r, m = eng.score(feats)
            best[shard] = min(best[shard],
                              (time.perf_counter() - t0) / reps)
    for shard in (1, 2):
        out[f"{shard}/{b}"] = best[shard]
    # parity while we're here: sharded must equal unsharded bit-for-bit
    e1 = ScoringEngine(model, use_sparse=False)
    e2 = ScoringEngine(model, use_sparse=False, shard=2)
    q = feats[: min(1024, b)]
    r1, m1 = e1.score(q); r2, m2 = e2.score(q)
    assert np.array_equal(r1, r2) and np.array_equal(m1, m2)
print("RESULT " + json.dumps(out))
"""


def _scoring_rows(buckets, reps):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCORING_SCRIPT, json.dumps(list(buckets)),
         str(SCORING_GRID), str(reps)],
        env=env, capture_output=True, text=True, timeout=1800)
    line = next((ln for ln in proc.stdout.splitlines()
                 if ln.startswith("RESULT ")), None)
    if line is None:
        raise RuntimeError("scoring subprocess failed:\n"
                           + proc.stdout + "\n---\n" + proc.stderr)
    timings = json.loads(line[len("RESULT "):])
    rows = []
    for b in buckets:
        for shard in (1, 2):
            dt = timings[f"{shard}/{b}"]
            rps = b / dt
            rows.append((f"scale/scoring/shard={shard}/b={b}", dt * 1e6,
                         f"rows_per_s={rps:.0f} g={SCORING_GRID}", rps))
        ratio = timings[f"1/{b}"] / timings[f"2/{b}"]
        rows.append((f"scale/scoring/shard_speedup/b={b}", 0.0,
                     f"x{ratio:.2f} (accept >= 1.5x at largest bucket)",
                     ratio))
    return rows


def run(smoke: bool = False):
    if smoke:
        rows = _fit_rows(n_list=(2000,), stream_only=(), iters=2)
        rows += _scoring_rows(buckets=(4096,), reps=3)
        return rows
    rows = _fit_rows(n_list=(10_000, 100_000, 200_000, 1_000_000),
                     stream_only=(1_000_000,), iters=2)
    rows += _scoring_rows(buckets=SCORING_BUCKETS, reps=12)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(smoke="--smoke" in sys.argv):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
