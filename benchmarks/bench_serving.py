"""Serving benchmark: batched vs per-request scoring, sparse vs dense.

Rows are (name, us_per_call, derived[, value]) — ``derived`` stays a
human-readable string for the CSV; ``value``, when present, is the same
headline number as a float so run.py's smoke gate and the JSON emitter
never parse strings:
  * serving/naive_loop      — 1 jit call per request (the no-batching bar)
  * serving/batched         — RiskService micro-batches of ``max_batch``
  * serving/batch_speedup   — req/s ratio (acceptance: >= 5x at batch 64);
    value = the ratio itself
  * serving/dense|sparse/p=… — risk scoring path cost incl. the host-side
    feature transfer; the k-sparse path ships (b, k) instead of (b, p)
  * serving/latency         — p50/p99 from the service instrumentation
"""
import time

import numpy as np

from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.serving import ScoringEngine, RiskService, fit_survival_model


def _model(n, p, k, seed=0):
    x, t, delta, beta_star = make_correlated_survival(
        SyntheticSpec(n=n, p=p, k=k, rho=0.5, seed=seed, censor_scale=3.0))
    # serve the ground-truth-sparse beta: the bench measures scoring, not
    # fitting, so any k-sparse coefficient vector exercises the same path
    return x, fit_survival_model(x, t, delta, beta_star)


def run(smoke: bool = False):
    rows = []
    n_req = 64 if smoke else 256
    max_batch = 16 if smoke else 64
    n_train = 256 if smoke else 2000

    # -- batched vs naive per-request (dense p=64) -------------------------
    x, model = _model(n_train, 64, 6)
    rng = np.random.default_rng(1)
    feats = rng.standard_normal((n_req, 64)).astype(np.float32)

    eng_naive = ScoringEngine(model, use_sparse=False)
    eng_naive.risk_scores(feats[:1])          # warm the bucket-1 jit
    eng_naive.median_survival(feats[:1])
    t0 = time.perf_counter()
    for i in range(n_req):
        eng_naive.risk_scores(feats[i:i + 1])
        eng_naive.median_survival(feats[i:i + 1])
    dt_naive = time.perf_counter() - t0
    rps_naive = n_req / dt_naive
    rows.append(("serving/naive_loop", dt_naive / n_req * 1e6,
                 f"reqs_per_s={rps_naive:.0f}", rps_naive))

    eng = ScoringEngine(model, use_sparse=False)
    svc = RiskService(eng, max_batch=max_batch)
    for i in range(max_batch):                # warm the full-bucket jit
        svc.submit(feats[i % len(feats)])
    svc.drain()
    svc = RiskService(eng, max_batch=max_batch)
    t0 = time.perf_counter()
    for i in range(n_req):
        svc.submit(feats[i])
    svc.drain()
    dt_batch = time.perf_counter() - t0
    rps_batch = n_req / dt_batch
    st = svc.stats()
    speedup = rps_batch / rps_naive
    rows.append(("serving/batched", dt_batch / n_req * 1e6,
                 f"reqs_per_s={rps_batch:.0f}", rps_batch))
    rows.append(("serving/batch_speedup", 0.0,
                 f"x{speedup:.1f} (accept >= 5x)", speedup))
    rows.append(("serving/latency", 0.0,
                 f"p50={st.get('latency_p50_ms', 0):.2f}ms "
                 f"p99={st.get('latency_p99_ms', 0):.2f}ms "
                 f"mean_batch={st['mean_batch']:.0f}",
                 st.get("latency_p99_ms", 0.0)))

    # -- sparse vs dense risk scoring --------------------------------------
    b = 64 if smoke else 1024
    reps = 3 if smoke else 10
    for p in ((1000,) if smoke else (1000, 4000)):
        xs, model_s = _model(n_train, p, 8, seed=2)
        qx = rng.standard_normal((b, p)).astype(np.float32)
        for label, sparse in (("dense", False), ("sparse", True)):
            eng_p = ScoringEngine(model_s, use_sparse=sparse)
            eng_p.risk_scores(qx)             # warm
            t0 = time.perf_counter()
            for _ in range(reps):
                eng_p.risk_scores(qx)
            us = (time.perf_counter() - t0) / reps * 1e6
            rows.append((f"serving/{label}/p={p},b={b}", us,
                         f"k={model_s.k if sparse else p}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
