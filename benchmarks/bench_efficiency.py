"""Paper Fig. 1 + Appendix D.1: optimization efficiency.

For each solver: loss-vs-iteration trace (monotonicity check) and
wall-clock per sweep/iteration, on l2 and l1+l2 regularized problems with
the paper's lambda grid. Emits CSV rows name,us_per_call,derived where
`derived` is the final objective (and a MONO/NONMONO tag in the name of
the trace file written next to the results).
"""
import json
import os
import time

import numpy as np

from repro.core import cox, solvers
from repro.data.synthetic import SyntheticSpec, make_correlated_survival

OUT = os.path.join(os.path.dirname(__file__), "results")


def run(n=2000, p=150, n_iters=40):
    x, t, delta, _ = make_correlated_survival(
        SyntheticSpec(n=n, p=p, k=10, rho=0.5, seed=0))
    data = cox.prepare(x, t, delta)
    rows = []
    traces = {}
    for lam1, lam2 in ((0.0, 1.0), (0.0, 5.0), (1.0, 1.0), (1.0, 5.0)):
        for method in ("cd_quad", "cd_cubic", "newton", "quasi_newton",
                       "prox_newton", "gd"):
            if method == "newton" and lam1 > 0:
                continue  # paper: exact Newton inapplicable to l1
            fn = solvers.SOLVERS[method]
            res = fn(data, lam1, lam2, n_iters)      # compile
            res.objective.block_until_ready()
            t0 = time.perf_counter()
            res = fn(data, lam1, lam2, n_iters)
            res.objective.block_until_ready()
            dt = time.perf_counter() - t0
            obj = np.asarray(res.objective)
            fin = obj[np.isfinite(obj)]
            # relative tolerance: f32 accumulation noise near the optimum
            # is O(1e-7) of the objective (verified monotone in f64)
            tol = 1e-6 * max(abs(float(fin[0])), 1.0) if fin.size else 0.0
            mono = bool(np.all(np.diff(fin) <= tol)
                        and np.all(np.isfinite(obj)))
            name = f"efficiency/{method}/lam1={lam1}/lam2={lam2}"
            final = float(obj[-1]) if np.isfinite(obj[-1]) else float("inf")
            rows.append((name, dt / n_iters * 1e6,
                         f"final={final:.4f};monotone={mono}"))
            traces[name] = obj.tolist()
    # --- blow-up regime (paper Fig. 1a / Figs. 5, 13): rare heavy-tailed
    # features make the risk-set variance vanish at beta=0; raw Newton
    # overshoots into the loss's linear tail while ours stays monotone.
    rng = np.random.default_rng(1)
    nb, pb = 400, 8
    xb = ((rng.uniform(size=(nb, pb)) < 0.04)
          * rng.lognormal(1.5, 1.0, size=(nb, pb))).astype(np.float32)
    risk = np.clip(xb @ (np.resize([3.0, -3.0], pb)), -30, 30)
    tb = (-np.log(rng.uniform(1e-12, 1, nb)) / np.exp(risk)) ** 0.3
    db = (rng.uniform(size=nb) < 0.8).astype(np.float32)
    data_b = cox.prepare(xb, tb.astype(np.float32), db)
    for method in ("cd_quad", "cd_cubic", "newton", "quasi_newton",
                   "prox_newton"):
        res = solvers.SOLVERS[method](data_b, 0.0, 0.0, 15)
        obj = np.asarray(res.objective)
        fin = obj[np.isfinite(obj)]
        blew_up = (not np.all(np.isfinite(obj))) or \
            (fin.size and float(fin.max()) > float(obj[0]) * 1.5)
        mono = bool(np.all(np.isfinite(obj))
                    and np.all(np.diff(obj) <= 1e-6 * abs(obj[0])))
        rows.append((f"efficiency_blowup/{method}", 0.0,
                     f"blew_up={blew_up};monotone={mono}"))
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "efficiency_traces.json"), "w") as f:
        json.dump(traces, f)
    return rows
