"""Batched serving example: continuous batched prefill+decode over a
request queue on a reduced Mixtral (MoE + sliding-window rolling cache).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "mixtral-8x7b", "--reduced", "--requests", "8",
          "--prompt-len", "20", "--max-new", "12"])
