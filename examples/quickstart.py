"""Quickstart: FastSurvival CPH training in ~40 lines.

Generates the paper's Appendix-C synthetic data, fits the CPH model with
the quadratic- and cubic-surrogate coordinate descent, compares against the
Newton baselines on the same objective, and evaluates CIndex/F1.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cox, solvers
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.survival import metrics


def main():
    spec = SyntheticSpec(n=1000, p=100, k=8, rho=0.7, seed=0)
    x, t, delta, beta_star = make_correlated_survival(spec)
    data = cox.prepare(x, t, delta)
    print(f"n={spec.n} p={spec.p} events={int(delta.sum())}")

    results = {}
    for method in ("cd_quad", "cd_cubic", "quasi_newton", "prox_newton",
                   "newton_ls", "gd"):
        res = solvers.SOLVERS[method](data, 0.0, 1.0, 60)
        obj = np.asarray(res.objective)
        results[method] = res
        mono = "monotone" if np.all(np.diff(obj) <= 1e-7) else "NON-MONOTONE"
        print(f"{method:>14}: final objective {obj[-1]:.6f}  [{mono}]")

    beta = np.asarray(results["cd_quad"].beta)
    risk = x @ beta
    ci = metrics.cindex(t, delta, risk)
    print(f"\ncd_quad: CIndex {ci:.4f}")

    # l1-regularized sparse fit
    res = solvers.fit_cd(data, lam1=5.0, lam2=1.0, n_iters=100)
    b = np.asarray(res.beta)
    p_, r_, f1 = metrics.support_f1(beta_star, b)
    print(f"l1 fit: support {int((np.abs(b) > 1e-8).sum())}, "
          f"F1 vs true support {f1:.3f}")


if __name__ == "__main__":
    main()
