"""End-to-end driver: train a Mamba2 backbone with the paper's CPH
objective (deep survival head), beam-search a sparse interpretable head on
the frozen features, export the result as a serving artifact, and score it
through the production registry/service stack.

Default runs a CPU-sized variant; pass --full for the ~100M config
(mamba2-130m at 12 layers; a few hundred steps is hours on 1 CPU core,
minutes on accelerators — the step function is the same one the dry-run
lowers at pod scale).

    PYTHONPATH=src python examples/train_survival_lm.py --steps 200
"""
import argparse
import os
import tempfile

import numpy as np

from repro.serving import ModelRegistry, RiskService
from repro.survival import deep
from repro.survival.metrics import cindex


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config instead of the CPU-sized one")
    ap.add_argument("--export", default="",
                    help="artifact directory (default: a temp dir)")
    args = ap.parse_args(argv)

    dcfg = deep.DeepSurvivalConfig(steps=args.steps, batch=args.batch,
                                   seq=args.seq, full=args.full)
    cfg = deep.model_config(dcfg)
    print(f"[driver] arch={cfg.name} family={cfg.family} "
          f"d_model={cfg.d_model} objective=cox")

    res = deep.run(dcfg)
    print(f"[driver] nll first10 {np.mean(res.losses[:10]):.4f} -> "
          f"last10 {np.mean(res.losses[-10:]):.4f}")
    print(f"[driver] held-out CIndex deep {res.cindex_deep:.4f} "
          f"(0.5 = random, higher is better)")
    print(f"[driver] beam-search sparse head: {res.nnz} of {cfg.d_model} "
          f"features, CIndex {res.cindex_sparse:.4f}")

    # -- export + serve: the deep artifact rides the linear serving stack --
    export_dir = args.export or os.path.join(
        tempfile.mkdtemp(prefix="deep_survival_"), "artifact")
    res.artifact.save(export_dir)
    print(f"[driver] artifact saved -> {export_dir}")

    svc = RiskService(engine=None, max_batch=16)
    reg = ModelRegistry(svc, prewarm_batches=(1, 16))
    reg.rollout("deep_v1", export_dir)     # checksum-verify + warm + swap
    svc.start()
    try:
        rids = [svc.submit(f) for f in res.features[:16]]
        served = np.array([svc.wait(r).risk for r in rids])
    finally:
        svc.stop()
    direct = np.exp(np.clip(res.features[:16] @ res.beta, -30.0, 30.0))
    np.testing.assert_allclose(served, direct, rtol=1e-4)
    ci_served = cindex(res.times, res.events,
                       np.asarray(reg.engine().risk_scores(res.features)))
    print(f"[driver] served {len(rids)} requests through "
          f"ModelRegistry/RiskService (gen {reg.generation}); "
          f"served CIndex {ci_served:.4f} — matches the sparse head")
    return res


if __name__ == "__main__":
    main()
