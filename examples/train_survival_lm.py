"""End-to-end driver: train a ~100M-parameter Mamba2 backbone with the
paper's CPH objective (deep survival head) for a few hundred steps, then
beam-search a sparse interpretable head on the frozen features.

Default runs a CPU-sized variant; pass --full for the ~100M config
(mamba2-130m at 12 layers; a few hundred steps is hours on 1 CPU core,
minutes on accelerators — the step function is the same one the dry-run
lowers at pod scale).

    PYTHONPATH=src python examples/train_survival_lm.py --steps 200
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import SurvivalTextStream
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.survival import metrics
from repro.survival.head import init_cox_head, pooled_features, sparse_refit
from repro.configs.base import TrainConfig
from repro.train.optimizer import init_opt_state
from repro.train.trainer import TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config instead of the CPU-sized one")
    args = ap.parse_args(argv)

    cfg = get_config("mamba2-130m")
    cfg = cfg.scaled(n_layers=12, vocab_size=2048) if args.full else \
        reduced_config(cfg).scaled(n_layers=4, d_model=128,
                                   vocab_size=512, ssm_state=32)
    model = build_model(cfg)
    n_params = sum(np.prod(x.shape) for x in jax.tree.leaves(
        jax.eval_shape(model.init_params, jax.random.PRNGKey(0))))
    print(f"[driver] arch=mamba2 family=ssm params={n_params/1e6:.1f}M "
          f"objective=cox")

    params = model.init_params(jax.random.PRNGKey(0))
    params["cox_head"] = init_cox_head(jax.random.PRNGKey(1), cfg.d_model)
    state = TrainState(params=params, opt=init_opt_state(params))
    tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=20,
                       total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, tcfg, objective="cox"))
    stream = SurvivalTextStream(cfg.vocab_size, args.seq, args.batch, seed=0)

    losses = []
    for step in range(args.steps):
        state, m = step_fn(state, stream.batch_for_step(step))
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"[driver] step {step} cox-nll {losses[-1]:.4f}")
    print(f"[driver] nll first10 {np.mean(losses[:10]):.4f} -> "
          f"last10 {np.mean(losses[-10:]):.4f}")

    # evaluation: CIndex of the learned risk on held-out batches
    feats, times, events, risks = [], [], [], []
    risk_fn = jax.jit(lambda p, b: model.risk_scores(p, b)[0])
    feat_fn = jax.jit(lambda p, b: pooled_features(model, p, b))
    for step in range(args.steps, args.steps + 4):
        b = stream.batch_for_step(step)
        risks.append(np.asarray(risk_fn(state.params, b)))
        feats.append(np.asarray(feat_fn(state.params, b)))
        times.append(b["time"])
        events.append(b["event"])
    t = np.concatenate(times)
    e = np.concatenate(events)
    ci = metrics.cindex(t, e, np.concatenate(risks))
    print(f"[driver] held-out CIndex {ci:.4f} "
          f"(0.5 = random, higher is better)")

    # the paper's technique as the final-layer trainer: sparse refit
    f = np.concatenate(feats)
    res = sparse_refit(f, t, e, k=min(8, cfg.d_model // 4))
    risk_sparse = f @ res.betas[-1]
    ci_s = metrics.cindex(t, e, risk_sparse)
    nz = int((np.abs(res.betas[-1]) > 1e-8).sum())
    print(f"[driver] beam-search sparse head: {nz} of {cfg.d_model} "
          f"features, CIndex {ci_s:.4f}")


if __name__ == "__main__":
    main()
