"""Cardinality-constrained variable selection (paper Fig. 2 regime).

Beam-search CD on highly correlated synthetic data (rho = 0.9) versus the
L1 path and a gradient-scored greedy OMP baseline; reports F1 per support
size and shows ours dominating under correlation.

    PYTHONPATH=src python examples/sparse_selection.py
"""
import numpy as np

from repro.core import beam, cox, path
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.survival import metrics


def main():
    spec = SyntheticSpec(n=600, p=120, k=6, rho=0.9, seed=3)
    x, t, delta, beta_star = make_correlated_survival(spec)
    data = cox.prepare(x, t, delta)
    k_true = int((beta_star != 0).sum())
    print(f"n={spec.n} p={spec.p} rho={spec.rho} true support={k_true}")

    res_beam = beam.beam_search(data, k=k_true + 2, beam_width=5, n_expand=8)
    res_omp = beam.omp_greedy(data, k=k_true + 2)
    res_l1 = path.l1_path(data, n_lambdas=20, lambda_min_ratio=0.02)

    print("\nsupport size | beam F1 | omp F1 | best-l1 F1")
    for k in range(1, k_true + 3):
        _, _, f_b = metrics.support_f1(beta_star, res_beam.betas[k - 1])
        _, _, f_o = metrics.support_f1(beta_star, res_omp.betas[k - 1])
        f_l = 0.0
        for b, s in zip(res_l1.betas, res_l1.support_sizes):
            if s == k:
                f_l = max(f_l, metrics.support_f1(beta_star, b)[2])
        print(f"{k:12d} | {f_b:7.3f} | {f_o:6.3f} | {f_l:10.3f}")


if __name__ == "__main__":
    main()
