"""End-to-end risk API: beam-search CPH -> artifact -> registry serving.

Fits a cardinality-constrained model with the paper's beam-search CD,
packages it as a SurvivalModel artifact (k-sparse beta + Breslow baseline
on a time grid), round-trips it through save/load (sha256-verified), and
serves risk / median-survival queries through the continuous-batching
RiskService fronted by a ModelRegistry: the engine is checksum-loaded
and jit-prewarmed before going live, queries carry priorities and
server-side deadlines, and a retrained model is hot-swapped into the
live slot mid-traffic with zero dropped requests — the O(k)-per-request
payoff of very sparse CPH models, with fleet-grade rollout semantics.

Telemetry is on by default here: spans go to ``$REPRO_TRACE_FILE`` when
set, else to ``serve_risk_api_trace.jsonl`` in the working directory, and
the run ends with the per-stage latency-breakdown table (queue wait vs
batch formation vs jit dispatch) rendered from that file.

    PYTHONPATH=src python examples/serve_risk_api.py
(or, with tcmalloc + the full env policy: scripts/launch.sh examples/serve_risk_api.py)
"""
import os
import tempfile

from repro.launch import runtime

runtime.apply()   # env/XLA/dtype policy before jax initializes

import numpy as np

from repro.analysis.report import latency_breakdown_table
from repro.core import beam, cox
from repro.data.synthetic import SyntheticSpec, make_correlated_survival
from repro.obs import trace
from repro.serving import (ModelRegistry, Priority, RiskService,
                           ScoringEngine, SurvivalModel,
                           fit_survival_model)


def main():
    trace_path = os.environ.get("REPRO_TRACE_FILE",
                                "serve_risk_api_trace.jsonl")
    if not os.environ.get("REPRO_TRACE_FILE"):
        if os.path.exists(trace_path):
            os.remove(trace_path)
        trace.configure(trace_path)
    print(f"[trace] spans -> {trace_path}")
    runtime.log()
    spec = SyntheticSpec(n=400, p=120, k=4, rho=0.7, seed=3,
                         censor_scale=3.0)
    x, t, delta, beta_star = make_correlated_survival(spec)
    data = cox.prepare(x, t, delta)
    k = int((beta_star != 0).sum())

    print(f"[fit] beam search, n={spec.n} p={spec.p} k={k}")
    res = beam.beam_search(data, k=k, beam_width=4, n_expand=6)
    beta = res.betas[-1]
    print(f"[fit] support={np.flatnonzero(beta).tolist()} "
          f"loss={res.losses[-1]:.2f}")

    model = fit_survival_model(x, t, delta, beta)
    with tempfile.TemporaryDirectory() as d:
        path = model.save(d + "/model")
        model = SurvivalModel.load(path)   # sha256-verified per leaf
    print(f"[artifact] p={model.p} k={model.k} grid={model.n_grid} "
          f"ties={model.ties} (save/load round-trip ok, checksums verified)")

    service = RiskService(None, max_batch=32, return_curves=False)
    registry = ModelRegistry(service)      # sparse fast path auto-selected
    entry = registry.load("champ", model)  # verify + build + warm buckets
    registry.swap("champ")                 # atomic promote to the live slot
    print(f"[registry] live={registry.live_id} "
          f"gen={registry.generation} warm_compiles={entry.compiles}")
    service.start()

    rng = np.random.default_rng(0)
    queries = rng.standard_normal((100, spec.p)).astype(np.float32)
    rids = [service.submit(q,
                           priority=(Priority.HIGH if i % 4 == 0
                                     else Priority.LOW),
                           deadline_s=None if i % 4 == 0 else 2.0)
            for i, q in enumerate(queries)]
    # hot-swap a retrained candidate mid-traffic: load + warm happen off
    # the serving path; queued requests score on the new engine, zero drops
    retrained = fit_survival_model(x, t, delta,
                                   (beta * 0.95).astype(np.float32))
    registry.rollout("retrain", retrained)
    rids += [service.submit(q, priority=Priority.HIGH) for q in queries[:20]]
    responses = [service.wait(rid) for rid in rids]
    service.stop()

    st = service.stats()
    print(f"[serve] {st['n_requests']} requests in {st['wall_s']*1e3:.1f}ms "
          f"({st['reqs_per_s']:.0f} req/s, mean batch "
          f"{st['mean_batch']:.1f}, p50 {st['latency_p50_ms']:.2f}ms, "
          f"p99 {st['latency_p99_ms']:.2f}ms, queue_depth "
          f"{st['queue_depth']}, rejected {st['rejected_count']}, "
          f"shed {st['shed_count']}, expired {st['expired_count']}, "
          f"errors {st['error_count']}, timeouts {st['timeout_count']})")
    print(f"[serve] health={service.health()} engine_swaps="
          f"{st['engine_swaps']} live={registry.live_id} "
          f"gen={registry.generation}")
    ok = [r for r in responses if r.ok]
    print(f"[serve] {len(ok)}/{len(responses)} scored ok "
          f"(every submitted rid reached a terminal outcome)")
    for r in ok[:3]:
        med = "inf" if np.isinf(r.median) else f"{r.median:.3f}"
        print(f"  req {r.rid}: risk={r.risk:.3f} median_survival={med} "
              f"trace={r.trace_id}")

    print("\nPer-stage latency breakdown (telemetry spans):\n")
    print(latency_breakdown_table(trace_path))
    return responses


if __name__ == "__main__":
    main()
